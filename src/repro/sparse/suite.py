"""The 25-matrix evaluation suite and the 625-pair test-case factory.

Mirrors the paper's Section VI protocol: 25 matrices with a wide compression-
ratio spread (Table II: CR(A^2) in [1.01, 28.34], rows 13k..16.7M, uniform /
power-law / banded-FEM structure), multiplied pairwise (25x25 = 625 cases)
with the paper's dimension-matching reshape rule.

Sizes are scaled to laptop/CI class (rows 20k..120k) so the full 625-case
reproduction runs in minutes on one CPU core, while keeping every matrix big
enough that sample_num = min(0.003*M, 300) stays in the paper's regime
(60..300 sampled rows).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator

import numpy as np

from .formats import CSR, match_dims
from . import random as sprand


@dataclasses.dataclass(frozen=True)
class SuiteEntry:
    name: str
    family: str
    build: Callable[[], CSR]


def _e(name: str, family: str, fn: Callable[[], CSR]) -> SuiteEntry:
    return SuiteEntry(name, family, fn)


# --------------------------------------------------------------------------- #
# 25 matrices.  Families and target CR(A^2) bands follow Table II:
#   er_*        CR ~ 1.0-1.6   (m133-b3, mc2depi, patents_main analogues)
#   pl_*        CR ~ 1.1-2.0   (webbase-1M, scircuit analogues)
#   rmat_*      CR ~ 1.8-3.0   (delaunay/cage analogues)
#   band_*      CR ~ 3-8       (offshore, filter3D, conf5 analogues)
#   fem_*       CR ~ 12-30     (cant, hood, consph, pwtk, pdb1HYS analogues)
# --------------------------------------------------------------------------- #
SUITE: tuple[SuiteEntry, ...] = (
    _e("er_120k_d3",    "er",   lambda: sprand.erdos_renyi(120_000, 120_000, 3, seed=101)),
    _e("er_100k_d4",    "er",   lambda: sprand.erdos_renyi(100_000, 100_000, 4, seed=102)),
    _e("er_80k_d6",     "er",   lambda: sprand.erdos_renyi(80_000, 80_000, 6, seed=103)),
    _e("er_60k_d8",     "er",   lambda: sprand.erdos_renyi(60_000, 60_000, 8, seed=104)),
    _e("er_40k_d12",    "er",   lambda: sprand.erdos_renyi(40_000, 40_000, 12, seed=105)),
    _e("pl_100k_d4",    "pl",   lambda: sprand.power_law(100_000, 100_000, 4, 1.8, seed=201)),
    _e("pl_80k_d6",     "pl",   lambda: sprand.power_law(80_000, 80_000, 6, 1.6, seed=202)),
    _e("pl_60k_d8",     "pl",   lambda: sprand.power_law(60_000, 60_000, 8, 1.5, seed=203)),
    _e("pl_40k_d10",    "pl",   lambda: sprand.power_law(40_000, 40_000, 10, 1.4, seed=204)),
    _e("rmat_80k",      "rmat", lambda: sprand.rmat(80_000, 80_000, 400_000, seed=301, a=0.5, b=0.2, c=0.2)),
    _e("rmat_60k",      "rmat", lambda: sprand.rmat(60_000, 60_000, 300_000, seed=302, a=0.5, b=0.2, c=0.2)),
    _e("rmat_40k",      "rmat", lambda: sprand.rmat(40_000, 40_000, 200_000, seed=303, a=0.5, b=0.2, c=0.2)),
    _e("band_60k_d16",  "band", lambda: sprand.banded(60_000, 60_000, 16, 24, seed=401)),
    _e("band_50k_d20",  "band", lambda: sprand.banded(50_000, 50_000, 20, 26, seed=402)),
    _e("band_40k_d24",  "band", lambda: sprand.banded(40_000, 40_000, 24, 30, seed=403)),
    _e("band_40k_d28",  "band", lambda: sprand.banded(40_000, 40_000, 28, 32, seed=404)),
    _e("band_30k_d32",  "band", lambda: sprand.banded(30_000, 30_000, 32, 36, seed=405)),
    _e("fem_30k_d40",   "fem",  lambda: sprand.banded(30_000, 30_000, 40, 30, seed=501)),
    _e("fem_30k_d48",   "fem",  lambda: sprand.banded(30_000, 30_000, 48, 32, seed=502)),
    _e("fem_24k_d56",   "fem",  lambda: sprand.banded(24_000, 24_000, 56, 34, seed=503)),
    _e("fem_24k_d64",   "fem",  lambda: sprand.banded(24_000, 24_000, 64, 36, seed=504)),
    _e("fem_20k_d72",   "fem",  lambda: sprand.banded(20_000, 20_000, 72, 38, seed=505)),
    _e("fem_12k_d120",  "fem",  lambda: sprand.banded(12_000, 12_000, 120, 48, seed=506)),
    _e("femblk_20k",    "fem",  lambda: sprand.block_diag_fem(20_000, 20_000, 64, 0.9, seed=507)),
    _e("femblk_24k",    "fem",  lambda: sprand.block_diag_fem(24_000, 24_000, 48, 0.85, seed=508)),
)

assert len(SUITE) == 25

_CACHE: dict[str, CSR] = {}


def get_matrix(name: str) -> CSR:
    """Build (and cache) a suite matrix by name."""
    if name not in _CACHE:
        entry = next(e for e in SUITE if e.name == name)
        _CACHE[name] = entry.build()
    return _CACHE[name]


def mini_suite(scale: int = 20) -> list[tuple[str, CSR]]:
    """A fast reduced suite (rows ~ full/scale) for unit tests."""
    out = []
    specs = [
        ("mini_er", sprand.erdos_renyi(120_000 // scale, 120_000 // scale, 3, seed=11)),
        ("mini_pl", sprand.power_law(100_000 // scale, 100_000 // scale, 5, 1.6, seed=12)),
        ("mini_rmat", sprand.rmat(80_000 // scale, 80_000 // scale, 640_000 // scale, seed=13)),
        ("mini_band", sprand.banded(40_000 // scale, 40_000 // scale, 24, 30, seed=14)),
        ("mini_fem", sprand.banded(20_000 // scale, 20_000 // scale, 60, 34, seed=15)),
    ]
    out.extend(specs)
    return out


def degree_skew(m: CSR) -> dict:
    """Row-degree skew stats — what decides whether degree binning pays.

    ``skew`` is max/mean row degree: ~1 for uniform families (er, band, fem —
    global padding is already tight) and ≫1 for power-law/rmat (one hub row
    inflates every global-pad buffer; see ``repro.core.binning``).
    """
    deg = np.diff(m.rpt).astype(np.float64)
    mean = float(deg.mean()) if deg.size else 0.0
    mx = float(deg.max()) if deg.size else 0.0
    p99 = float(np.percentile(deg, 99)) if deg.size else 0.0
    return dict(max_deg=int(mx), mean_deg=round(mean, 3), p99_deg=int(p99),
                skew=round(mx / max(mean, 1e-9), 3))


def family_degree_skew(names: list[str] | None = None) -> dict[str, dict]:
    """Per-suite-entry skew stats, keyed by matrix name (family recorded)."""
    sel = names or [e.name for e in SUITE]
    out = {}
    for name in sel:
        entry = next(e for e in SUITE if e.name == name)
        stats = degree_skew(get_matrix(name))
        stats["family"] = entry.family
        out[name] = stats
    return out


def iter_cases(names: list[str] | None = None) -> Iterator[tuple[str, str, CSR, CSR]]:
    """All (A, B) pairs with the paper's reshape rule applied — 625 by default."""
    sel = names or [e.name for e in SUITE]
    for na in sel:
        a = get_matrix(na)
        for nb in sel:
            b = get_matrix(nb)
            am, bm = match_dims(a, b)
            yield na, nb, am, bm
