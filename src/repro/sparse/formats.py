"""Host-side sparse matrix substrate (numpy).

The paper stores all matrices in CSR (rpt / col / val, Fig. 1).  This module is
the host representation used by the data layer, the oracle implementations and
the test-case factory; the device (JAX) representation lives in
``repro.core.csr``.

No scipy in this environment — everything is built on numpy primitives.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class CSR:
    """Compressed Sparse Row matrix (host, numpy).

    Attributes mirror the paper's notation: ``rpt`` (row pointers, len M+1),
    ``col`` (column indices, sorted within a row), ``val`` (values).
    """

    rpt: np.ndarray  # int64 (M+1,)
    col: np.ndarray  # int32 (nnz,)
    val: np.ndarray  # float32 (nnz,)
    shape: tuple[int, int]

    # ------------------------------------------------------------------ #
    # basic properties
    # ------------------------------------------------------------------ #
    @property
    def nrows(self) -> int:
        return self.shape[0]

    @property
    def ncols(self) -> int:
        return self.shape[1]

    @property
    def nnz(self) -> int:
        return int(self.rpt[-1])

    @property
    def row_nnz(self) -> np.ndarray:
        """NNZ per row — ``NNZ(A_{i*})`` in the paper."""
        return np.diff(self.rpt)

    # ------------------------------------------------------------------ #
    # constructors
    # ------------------------------------------------------------------ #
    @staticmethod
    def from_coo(
        rows: np.ndarray,
        cols: np.ndarray,
        vals: Optional[np.ndarray],
        shape: tuple[int, int],
        *,
        dedup: bool = True,
        validate: bool = True,
    ) -> "CSR":
        """Build CSR from COO triplets; duplicates are summed when ``dedup``.

        ``validate`` (opt-out) runs ``repro.core.validate.validate_csr`` on
        the result so malformed triplets (out-of-range indices, non-finite
        values) raise a pinpointed ``OperandValidationError`` here instead
        of corrupting downstream kernels (DESIGN.md §9)."""
        from repro.core.errors import OperandValidationError
        m, n = shape
        rows = np.asarray(rows, dtype=np.int64)
        cols = np.asarray(cols, dtype=np.int64)
        if vals is None:
            vals = np.ones(rows.shape[0], dtype=np.float32)
        vals = np.asarray(vals, dtype=np.float32)
        if rows.size:
            if rows.min() < 0 or rows.max() >= m:
                bad = int(np.flatnonzero((rows < 0) | (rows >= m))[0])
                raise OperandValidationError(
                    f"COO row index {int(rows[bad])} out of range [0, {m})",
                    field="row", index=bad, observed=int(rows[bad]),
                    planned=m)
            if cols.min() < 0 or cols.max() >= n:
                bad = int(np.flatnonzero((cols < 0) | (cols >= n))[0])
                raise OperandValidationError(
                    f"COO col index {int(cols[bad])} out of range [0, {n})",
                    field="col", index=bad, observed=int(cols[bad]),
                    planned=n)
        keys = rows * n + cols
        order = np.argsort(keys, kind="stable")
        keys, vals = keys[order], vals[order]
        if dedup and keys.size:
            uniq, inv = np.unique(keys, return_inverse=True)
            summed = np.zeros(uniq.shape[0], dtype=np.float64)
            np.add.at(summed, inv, vals.astype(np.float64))
            keys, vals = uniq, summed.astype(np.float32)
        out_rows = (keys // n).astype(np.int64)
        out_cols = (keys % n).astype(np.int32)
        rpt = np.zeros(m + 1, dtype=np.int64)
        np.add.at(rpt, out_rows + 1, 1)
        np.cumsum(rpt, out=rpt)
        out = CSR(rpt=rpt, col=out_cols, val=vals, shape=(m, n))
        if validate:
            from repro.core.validate import validate_csr
            validate_csr(out, name="from_coo", allow_duplicates=not dedup)
        return out

    @staticmethod
    def from_dense(a: np.ndarray, *, validate: bool = True) -> "CSR":
        rows, cols = np.nonzero(a)
        return CSR.from_coo(rows, cols, a[rows, cols].astype(np.float32),
                            a.shape, validate=validate)

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=np.float32)
        rows = np.repeat(np.arange(self.nrows), self.row_nnz)
        out[rows, self.col] = self.val
        return out

    # ------------------------------------------------------------------ #
    # the paper's dimension-matching reshape rule (Section VI-A)
    # ------------------------------------------------------------------ #
    def keep_left_cols(self, k: int) -> "CSR":
        """Keep the left ``k`` columns (paper: reshape A when K_A > rows(B))."""
        assert k <= self.ncols
        mask = self.col < k
        rows = np.repeat(np.arange(self.nrows), self.row_nnz)[mask]
        return CSR.from_coo(rows, self.col[mask], self.val[mask], (self.nrows, k), dedup=False)

    def keep_top_rows(self, k: int) -> "CSR":
        """Keep the top ``k`` rows (paper: reshape B when rows(B) > K_A)."""
        assert k <= self.nrows
        end = int(self.rpt[k])
        return CSR(
            rpt=self.rpt[: k + 1].copy(),
            col=self.col[:end].copy(),
            val=self.val[:end].copy(),
            shape=(k, self.ncols),
        )

    def transpose(self) -> "CSR":
        rows = np.repeat(np.arange(self.nrows), self.row_nnz)
        return CSR.from_coo(self.col.astype(np.int64), rows, self.val, (self.ncols, self.nrows))


def match_dims(a: CSR, b: CSR) -> tuple[CSR, CSR]:
    """Apply the paper's reshape rule so that ``a @ b`` is well-defined.

    'If the dimensions of the two input matrices are 10x10 and 5x5, we reshape
    the first matrix to a 10x5 matrix by keeping its left 5 columns.  If the
    dimensions are 5x5 and 10x10, we reshape the second to 5x10 by keeping
    its top 5 rows.'
    """
    if a.ncols == b.nrows:
        return a, b
    if a.ncols > b.nrows:
        return a.keep_left_cols(b.nrows), b
    return a, b.keep_top_rows(a.ncols)


def spgemm_dense_oracle(a: CSR, b: CSR) -> np.ndarray:
    """Tiny-scale dense oracle for numeric tests (O(M*K*N) memory)."""
    return a.to_dense() @ b.to_dense()
