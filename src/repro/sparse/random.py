"""Synthetic sparse matrix generators.

The paper evaluates on 25 SuiteSparse matrices chosen for their *diversity of
compression ratio* (Table II: CR of A^2 from 1.01 to 28.34) and row-degree
structure (uniform rows like m133-b3, power-law rows like webbase-1M, banded
FEM matrices like cant/pdb1HYS).  SuiteSparse is not available offline, so
these generators reproduce the structural families that drive that CR spread:

* ``erdos_renyi``   — uniform random columns; products rarely collide → CR ≈ 1.
  (paper analogues: m133-b3, mc2depi, patents_main)
* ``power_law``     — Zipf row degrees + hub columns; mild collision → CR 1–3.
  (analogues: webbase-1M, patents_main, scircuit)
* ``banded``        — columns confined to a diagonal band; dense bands make
  products collide heavily → CR grows with nnz/row vs band width.
  (analogues: cant, hood, consph, shipsec1, pwtk, pdb1HYS)
* ``rmat``          — recursive power-law graph (graph-analytics analogue,
  cage*/delaunay-like mid CR).

All generators are deterministic in ``seed`` and return host ``CSR``.
"""
from __future__ import annotations

import numpy as np

from .formats import CSR


def _dedup_rowwise(rows: np.ndarray, cols: np.ndarray, shape) -> CSR:
    return CSR.from_coo(rows, cols, None, shape, dedup=True)


def erdos_renyi(m: int, n: int, nnz_per_row: int, seed: int) -> CSR:
    """Uniform random columns, ~Poisson row degree around ``nnz_per_row``."""
    rng = np.random.default_rng(seed)
    deg = rng.poisson(nnz_per_row, size=m).clip(1, n)
    rows = np.repeat(np.arange(m, dtype=np.int64), deg)
    cols = rng.integers(0, n, size=rows.shape[0], dtype=np.int64)
    return _dedup_rowwise(rows, cols, (m, n))


def power_law(m: int, n: int, avg_nnz: int, alpha: float, seed: int) -> CSR:
    """Zipf-ish row degrees and hub-biased columns (web/citation-like)."""
    rng = np.random.default_rng(seed)
    # Row degrees: Pareto tail scaled to the requested mean, clipped.
    raw = rng.pareto(alpha, size=m) + 1.0
    deg = np.maximum(1, (raw * (avg_nnz / raw.mean())).astype(np.int64))
    deg = deg.clip(1, min(n, 50 * avg_nnz))
    rows = np.repeat(np.arange(m, dtype=np.int64), deg)
    # Hub columns: squared-uniform bias toward low indices.
    u = rng.random(rows.shape[0])
    cols = (u * u * n).astype(np.int64).clip(0, n - 1)
    return _dedup_rowwise(rows, cols, (m, n))


def banded(m: int, n: int, nnz_per_row: int, band: int, seed: int) -> CSR:
    """Columns near the scaled diagonal — FEM-like; high CR when band is tight."""
    rng = np.random.default_rng(seed)
    deg = np.full(m, nnz_per_row, dtype=np.int64)
    rows = np.repeat(np.arange(m, dtype=np.int64), deg)
    center = (rows.astype(np.float64) * n / m).astype(np.int64)
    off = rng.integers(-band, band + 1, size=rows.shape[0])
    cols = (center + off).clip(0, n - 1)
    return _dedup_rowwise(rows, cols, (m, n))


def rmat(m: int, n: int, nnz: int, seed: int, a=0.57, b=0.19, c=0.19) -> CSR:
    """R-MAT recursive generator (power-law graph, cage/delaunay analogue)."""
    rng = np.random.default_rng(seed)
    scale_r = int(np.ceil(np.log2(max(m, 2))))
    scale_c = int(np.ceil(np.log2(max(n, 2))))
    scale = max(scale_r, scale_c)
    rows = np.zeros(nnz, dtype=np.int64)
    cols = np.zeros(nnz, dtype=np.int64)
    for _ in range(scale):
        r = rng.random(nnz)
        down = r >= a + b  # bottom half of the quadtree
        right = ((r >= a) & (r < a + b)) | (r >= a + b + c)
        rows = rows * 2 + down
        cols = cols * 2 + right
    rows = rows % m
    cols = cols % n
    return _dedup_rowwise(rows, cols, (m, n))


def block_diag_fem(m: int, n: int, block: int, fill: float, seed: int) -> CSR:
    """Overlapping near-dense diagonal blocks (pdb1HYS-like, very high CR)."""
    rng = np.random.default_rng(seed)
    nblocks = max(1, m // block)
    rows_list, cols_list = [], []
    for bi in range(nblocks):
        r0 = bi * block
        c0 = int(r0 * n / m)
        bh = min(block, m - r0)
        bw = min(int(block * n / m) + block // 2, n - c0)
        if bw <= 0:
            continue
        cnt = int(fill * bh * bw)
        rows_list.append(r0 + rng.integers(0, bh, size=cnt))
        cols_list.append(c0 + rng.integers(0, bw, size=cnt))
    rows = np.concatenate(rows_list)
    cols = np.concatenate(cols_list)
    return _dedup_rowwise(rows, cols, (m, n))
