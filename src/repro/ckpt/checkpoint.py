"""Sharded, atomic, mesh-agnostic checkpointing (fault tolerance substrate).

Design (DESIGN §7):
  * **atomic two-phase commit** — shard files are written to a ``.tmp``
    step directory, fsync'd, then the directory is renamed and a manifest
    written last; a crash mid-write can never corrupt the latest checkpoint.
  * **mesh-agnostic layout** — every leaf is saved UNSHARDED (gathered) with
    its pytree path; restore lays it out for whatever mesh/sharding the
    restarting job provides (elastic rescale = restore on a different mesh).
    At true pod scale the gather becomes per-host shard files; the format
    keeps a ``shards`` field so that path is additive, not breaking.
  * **pipeline state inside the checkpoint** — step and data-rng travel with
    the params, so restart resumes the exact batch stream (pipeline.py is
    pure in (seed, step)).
  * retention: keep the newest ``keep`` checkpoints, delete older ones.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return leaves, treedef


def _key_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return "/".join(out)


def save(ckpt_dir: str, step: int, tree, *, extra: dict | None = None,
         keep: int = 3) -> str:
    """Atomically save ``tree`` (params/opt state/…) at ``step``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, _ = _flatten(tree)
    index = []
    arrays = {}
    for i, (path, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        arrays[f"a{i}"] = arr
        index.append(dict(key=_key_str(path), idx=i,
                          shape=list(arr.shape), dtype=str(arr.dtype)))
    with open(os.path.join(tmp, "shard_0.npz"), "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    manifest = dict(step=step, index=index, shards=["shard_0.npz"],
                    extra=extra or {})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)  # atomic commit
    _retain(ckpt_dir, keep)
    return final


_ASYNC: dict[str, "object"] = {}


def save_async(ckpt_dir: str, step: int, tree, *, extra: dict | None = None,
               keep: int = 3):
    """Non-blocking checkpoint: snapshot to host, write in a daemon thread.

    The training loop resumes immediately after the device→host copy; the
    atomic rename still guarantees crash consistency.  ``wait_async`` joins
    the in-flight write (call before shutdown / the next async save)."""
    import threading
    wait_async(ckpt_dir)
    host_tree = jax.tree_util.tree_map(
        lambda a: np.asarray(jax.device_get(a)), tree)
    t = threading.Thread(target=save,
                         args=(ckpt_dir, step, host_tree),
                         kwargs=dict(extra=extra, keep=keep), daemon=True)
    t.start()
    _ASYNC[ckpt_dir] = t
    return t


def wait_async(ckpt_dir: str):
    t = _ASYNC.pop(ckpt_dir, None)
    if t is not None:
        t.join()


def _retain(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, target_tree, *, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``target_tree`` (shapes must match).

    ``shardings``: optional matching pytree of NamedSharding — the restored
    arrays are placed directly into the *new* mesh layout (elastic restart).
    Returns (tree, extra, step).
    """
    step = latest_step(ckpt_dir) if step is None else step
    assert step is not None, f"no checkpoint under {ckpt_dir}"
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "shard_0.npz"))
    by_key = {e["key"]: data[f"a{e['idx']}"] for e in manifest["index"]}

    leaves, treedef = _flatten(target_tree)
    shard_leaves = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec"))
        if shardings is not None else [None] * len(leaves))
    out = []
    for (path, leaf), sh in zip(leaves, shard_leaves):
        key = _key_str(path)
        assert key in by_key, f"checkpoint missing {key}"
        arr = by_key[key]
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, out)
    return tree, manifest.get("extra", {}), step
