"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention block every 7th
slot (one weight set reused, the Zamba trick).  ssm_state=64.
Long-context serving uses a 4096-token sliding window on the shared attention
(sub-quadratic; see DESIGN.md §6).  [arXiv:2411.15242]
"""
from .base import ModelConfig, register

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    block_pattern=("mamba",),
    ssm_state_dim=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    attn_every=7, sliding_window=4096,
)

SMOKE = ModelConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=512,
    block_pattern=("mamba",),
    ssm_state_dim=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=16,
    attn_every=2, sliding_window=64, dtype="float32",
)

register(CONFIG, SMOKE)
