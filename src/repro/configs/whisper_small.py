"""whisper-small [audio] — enc-dec backbone; conv frontend is a STUB
(``input_specs`` provides precomputed frame embeddings).  [arXiv:2212.04356]
"""
from .base import ModelConfig, register

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=51865,
    num_encoder_layers=12, encoder_seq_len=1500,
    norm="layernorm", act="gelu", frontend="audio_stub",
    tensor_parallel=False,   # 0.3B on 256 chips: DP over both mesh axes
)

SMOKE = ModelConfig(
    name="whisper-small", family="encdec",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=512,
    num_encoder_layers=2, encoder_seq_len=32,
    norm="layernorm", act="gelu", frontend="audio_stub", dtype="float32",
)

register(CONFIG, SMOKE)
