"""xlstm-125m [ssm] — sLSTM + mLSTM blocks (7:1-ish ratio → 3:1 over 12L).

No separate MLP (d_ff=0): xLSTM blocks integrate up/down projections.
[arXiv:2405.04517]
"""
from .base import ModelConfig, register

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    ssm_expand=2, ssm_chunk=256, norm="layernorm",
    tensor_parallel=False,   # 0.19B on 256 chips: DP over both mesh axes
)

SMOKE = ModelConfig(
    name="xlstm-125m", family="ssm",
    num_layers=4, d_model=64, num_heads=2, num_kv_heads=2,
    d_ff=0, vocab_size=512,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    ssm_expand=2, ssm_chunk=16, norm="layernorm", dtype="float32",
)

register(CONFIG, SMOKE)
