"""qwen2-vl-72b [vlm] — M-RoPE, dynamic-resolution vision frontend (stub).

Backbone only per assignment; ``input_specs`` provides precomputed patch
embeddings.  [arXiv:2409.12191]
"""
from .base import ModelConfig, register

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=29568, vocab_size=152064,
    qkv_bias=True, rope_theta=1e6,
    mrope_sections=(16, 24, 24),   # t/h/w sections of head_dim//2 = 64
    frontend="vision_stub",
    fsdp=True, opt_state_dtype="bfloat16", remat="full",
)

SMOKE = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512, qkv_bias=True,
    mrope_sections=(2, 3, 3), frontend="vision_stub", dtype="float32",
)

register(CONFIG, SMOKE)
