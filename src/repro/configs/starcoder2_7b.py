"""starcoder2-7b [dense] — GQA kv=4, RoPE, layernorm+gelu.  [arXiv:2402.19173]"""
from .base import ModelConfig, register

CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense",
    num_layers=32, d_model=4608, num_heads=36, num_kv_heads=4,
    d_ff=18432, vocab_size=49152,
    qkv_bias=True, norm="layernorm", act="gelu", rope_theta=1e5,
)

SMOKE = ModelConfig(
    name="starcoder2-7b", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512, norm="layernorm", act="gelu", dtype="float32",
)

register(CONFIG, SMOKE)
