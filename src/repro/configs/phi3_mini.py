"""phi3-mini-3.8b [dense] — RoPE SwiGLU GQA (MHA: kv=heads).  [arXiv:2404.14219]"""
from .base import ModelConfig, register

CONFIG = ModelConfig(
    name="phi3-mini-3.8b", family="dense",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32064,
    rope_theta=1e4,
)

SMOKE = ModelConfig(
    name="phi3-mini-3.8b", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=512, dtype="float32",
)

register(CONFIG, SMOKE)
