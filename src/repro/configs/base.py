"""ModelConfig: the single config type covering all assigned families.

Each assigned architecture gets one file in this package defining ``CONFIG``
(the exact published shape) and ``smoke_config()`` (a reduced same-family
variant for CPU tests).  ``registry()`` maps arch ids to configs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 → d_model // num_heads
    # --- attention ---
    attention_type: str = "gqa"       # gqa | mla
    qkv_bias: bool = False
    rope_theta: float = 1e4
    mrope_sections: tuple[int, ...] = ()   # qwen2-vl M-RoPE (sums to head_dim//2)
    # --- MLA (deepseek-v3) ---
    mla_q_lora_rank: int = 0
    mla_kv_lora_rank: int = 0
    mla_qk_nope_dim: int = 0
    mla_qk_rope_dim: int = 0
    mla_v_dim: int = 0
    # --- MoE ---
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_shared_experts: int = 0
    moe_dense_layers: int = 0         # leading dense layers (deepseek: 3)
    moe_capacity_factor: float = 1.25
    mtp_heads: int = 0                # deepseek multi-token prediction depth
    # --- SSM / hybrid ---
    block_pattern: tuple[str, ...] = ()  # cycled over layers; empty → ("attn",)
    ssm_state_dim: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    attn_every: int = 0               # zamba2: shared attn block every k layers
    # --- enc-dec (whisper) ---
    num_encoder_layers: int = 0
    encoder_seq_len: int = 1500
    # --- frontend stubs ---
    frontend: str = "none"            # none | audio_stub | vision_stub
    # --- misc ---
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    act: str = "swiglu"               # swiglu | gelu
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # --- distribution hints (launch-time) ---
    tensor_parallel: bool = True      # False: replicate weights, batch shards
                                      # over (data × model) — right for <1B
                                      # models where TP shards starve the MXU
                                      # and per-layer all-reduces dominate
    fsdp: bool = False                # shard params over data axis too (ZeRO-3)
    opt_state_dtype: str = "float32"  # bfloat16 for the very large archs
    remat: str = "full"               # none | full | dots
    sliding_window: int = 0           # hybrid long-context serving window

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def is_encoder_decoder(self) -> bool:
        return self.num_encoder_layers > 0

    @property
    def layer_kinds(self) -> tuple[str, ...]:
        """Per-layer block kind, len == num_layers."""
        if not self.block_pattern:
            return ("attn",) * self.num_layers
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    def padded_heads(self, mesh_model: int) -> int:
        h = self.num_heads
        return -(-h // mesh_model) * mesh_model

    def padded_kv_heads(self, mesh_model: int) -> int:
        """MHA archs pad kv with q (group stays 1); GQA kv stays exact —
        q padding is chosen as a multiple of kv, and the decode cache shards
        over the sequence axis so kv never needs the mesh to divide it."""
        if self.num_kv_heads == self.num_heads:
            return self.padded_heads(mesh_model)
        return self.num_kv_heads

    def padded_vocab(self, multiple: int = 256) -> int:
        return -(-self.vocab_size // multiple) * multiple

    def param_count_estimate(self) -> int:
        """Rough parameter count (embeddings + blocks), for 6ND roofline."""
        from repro.models.transformer import build_schema
        from repro.models.schema import param_count
        return param_count(build_schema(self, mesh_model=1))

    def active_param_count_estimate(self) -> int:
        """Active params per token (MoE: top-k + shared experts only)."""
        total = self.param_count_estimate()
        if self.moe_num_experts == 0:
            return total
        e_ff = self.moe_d_ff or self.d_ff
        per_expert = 3 * self.d_model * e_ff
        moe_layers = self.num_layers - self.moe_dense_layers
        inactive = (self.moe_num_experts - self.moe_top_k) * per_expert * moe_layers
        return total - inactive


_REGISTRY: dict[str, "ModelConfig"] = {}
_SMOKE: dict[str, "ModelConfig"] = {}


def register(config: ModelConfig, smoke: ModelConfig) -> None:
    _REGISTRY[config.name] = config
    _SMOKE[config.name] = smoke


def registry() -> dict[str, ModelConfig]:
    from . import (qwen2_5_32b, phi3_mini, starcoder2_7b, qwen1_5_32b,  # noqa
                   qwen2_vl_72b, deepseek_v3_671b, llama4_scout,
                   xlstm_125m, zamba2_7b, whisper_small)
    return dict(_REGISTRY)


def smoke_registry() -> dict[str, ModelConfig]:
    registry()
    return dict(_SMOKE)


def get_config(name: str) -> ModelConfig:
    return registry()[name]


def get_smoke_config(name: str) -> ModelConfig:
    return smoke_registry()[name]
