"""llama4-scout-17b-a16e [moe] — 16 experts top-1 + shared, early fusion.

[hf:meta-llama/Llama-4-Scout-17B-16E]
"""
from .base import ModelConfig, register

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    rope_theta=5e5,
    moe_num_experts=16, moe_top_k=1, moe_d_ff=8192,
    moe_shared_experts=1, moe_dense_layers=0,
    fsdp=True, remat="full",
)

SMOKE = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512,
    moe_num_experts=4, moe_top_k=1, moe_d_ff=128,
    moe_shared_experts=1, dtype="float32",
)

register(CONFIG, SMOKE)
