"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP.

61L d_model=7168, 128 heads; first 3 layers dense (d_ff 18432), the rest MoE
with 2048-wide experts.  MLA: q_lora 1536, kv_lora 512, qk nope/rope 128/64,
v 128.  [arXiv:2412.19437]
"""
from .base import ModelConfig, register

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
    d_ff=18432, vocab_size=129280,
    attention_type="mla", head_dim=192,          # qk head dim = nope+rope
    mla_q_lora_rank=1536, mla_kv_lora_rank=512,
    mla_qk_nope_dim=128, mla_qk_rope_dim=64, mla_v_dim=128,
    moe_num_experts=256, moe_top_k=8, moe_d_ff=2048,
    moe_shared_experts=1, moe_dense_layers=3,
    mtp_heads=1,
    fsdp=True, opt_state_dtype="bfloat16", remat="full",
)

SMOKE = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=512,
    attention_type="mla", head_dim=48,
    mla_q_lora_rank=32, mla_kv_lora_rank=16,
    mla_qk_nope_dim=32, mla_qk_rope_dim=16, mla_v_dim=32,
    moe_num_experts=8, moe_top_k=2, moe_d_ff=64,
    moe_shared_experts=1, moe_dense_layers=1,
    mtp_heads=1, dtype="float32",
)

register(CONFIG, SMOKE)
