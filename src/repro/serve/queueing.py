"""Bounded request queue with deadlines for the SpGEMM service.

The queue is the service's backpressure valve (DESIGN.md §10): admission
never blocks — a request either takes a bounded slot (ADMITTED), or is shed
with a typed :class:`~repro.core.errors.AdmissionRejectedError` the moment
the queue is full.  Deadlines are absolute service-clock times checked at
every scheduling point; :meth:`BoundedQueue.expire` removes and returns
every request whose deadline passed while queued, so an overloaded service
degrades into *fast typed rejections*, never a silently growing backlog.

No threads: the service is a synchronous event loop (submit / step /
drain), which is what makes the chaos soak deterministic — every scheduling
decision happens at a visible program point.
"""
from __future__ import annotations

import collections

from repro.core.errors import AdmissionRejectedError


class BoundedQueue:
    """FIFO of requests with a hard capacity and deadline expiry.

    ``push`` raises :class:`AdmissionRejectedError` when full (the caller
    sheds the request); ``push_front`` re-admits a request the scheduler
    already holds (escalated retry, budget backpressure) ahead of the line
    and is allowed one transient slot over capacity — a requeue must never
    turn an admitted request into a shed one.
    """

    def __init__(self, capacity: int) -> None:
        self.capacity = int(capacity)
        if self.capacity <= 0:
            raise ValueError(f"queue capacity must be positive, "
                             f"got {capacity}")
        self._q: collections.deque = collections.deque()
        self.shed = 0        # counters for service stats
        self.expired = 0

    def __len__(self) -> int:
        return len(self._q)

    @property
    def full(self) -> bool:
        return len(self._q) >= self.capacity

    def push(self, req) -> None:
        if self.full:
            self.shed += 1
            raise AdmissionRejectedError(
                f"queue full ({len(self._q)}/{self.capacity}); request "
                f"{req.id} shed", reason="queue_full", request=req.id,
                observed=len(self._q), planned=self.capacity)
        self._q.append(req)

    def push_front(self, req) -> None:
        self._q.appendleft(req)

    def restore(self, reqs) -> None:
        """Return popped-but-not-dispatched requests to the tail in their
        original relative order (batch gathering passed over them); bypasses
        the capacity check for the same reason as :meth:`push_front`."""
        self._q.extend(reqs)

    def pop(self):
        return self._q.popleft() if self._q else None

    def expire(self, now: float) -> list:
        """Remove and return every queued request whose deadline passed."""
        if not self._q:
            return []
        live, dead = [], []
        for req in self._q:
            (dead if (req.deadline is not None and req.deadline <= now)
             else live).append(req)
        if dead:
            self._q = collections.deque(live)
            self.expired += len(dead)
        return dead

    def stats(self) -> dict:
        return dict(depth=len(self._q), capacity=self.capacity,
                    shed=self.shed, expired=self.expired)
