"""Batched serving engine: prefill + decode with static-shape KV caches.

Serving is two compiled programs:
  * ``prefill`` — full-sequence forward that also populates the cache for the
    prompt tokens (teacher-forced), returning the next-token logits;
  * ``decode_step`` — one token for the whole batch against the cache.

The engine keeps the cache on device across steps, supports greedy and
temperature sampling, and exposes the same serve_step the dry-run lowers.
Prefill here is implemented via sequential decode over prompt positions for
universal correctness across all five block families (attention caches could
batch-prefill; SSM states are inherently sequential) — fine at example scale,
and the 32k prefill *compute* path is exercised by the prefill_32k cell.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer as tmod


@dataclasses.dataclass
class ServeSession:
    cfg: Any
    params: Any
    cache: Any
    cur_len: jax.Array
    enc_out: Any = None


def make_decode_fn(cfg):
    @functools.partial(jax.jit, static_argnames=())
    def step(params, cache, tokens, cur_len, enc_out=None):
        return tmod.decode_step(params, cfg, tokens, cache, cur_len,
                                enc_out=enc_out)
    return step


def start_session(cfg, params, batch: int, max_len: int, *,
                  frame_embeds=None) -> ServeSession:
    cache = tmod.init_cache(cfg, batch, max_len)
    enc_out = None
    if cfg.is_encoder_decoder:
        assert frame_embeds is not None
        enc_out = tmod._run_encoder(params, cfg,
                                    frame_embeds.astype(jnp.dtype(cfg.dtype)))
    return ServeSession(cfg, params, cache, jnp.zeros((), jnp.int32), enc_out)


def prefill(session: ServeSession, prompt: jax.Array, decode_fn=None):
    """Feed prompt tokens (B, P) one position at a time; returns last logits."""
    decode_fn = decode_fn or make_decode_fn(session.cfg)
    logits = None
    for i in range(prompt.shape[1]):
        logits, session.cache = decode_fn(session.params, session.cache,
                                          prompt[:, i:i + 1], session.cur_len,
                                          session.enc_out)
        session.cur_len = session.cur_len + 1
    return logits


def generate(session: ServeSession, prompt: jax.Array, num_tokens: int, *,
             temperature: float = 0.0, seed: int = 0) -> jax.Array:
    """Greedy/temperature generation; returns (B, num_tokens) token ids."""
    decode_fn = make_decode_fn(session.cfg)
    logits = prefill(session, prompt, decode_fn)
    key = jax.random.PRNGKey(seed)
    out = []
    vocab = session.cfg.vocab_size
    tok = None
    for t in range(num_tokens):
        lg = logits[:, -1, :vocab]
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, lg / temperature, axis=-1)
        else:
            tok = jnp.argmax(lg, axis=-1)
        tok = tok[:, None].astype(jnp.int32)
        out.append(tok)
        logits, session.cache = decode_fn(session.params, session.cache, tok,
                                          session.cur_len, session.enc_out)
        session.cur_len = session.cur_len + 1
    return jnp.concatenate(out, axis=1)
