"""SpGEMM-as-a-service: a fault-contained request scheduler (DESIGN.md §10).

The plan cache + :class:`~repro.core.plan.TemplateRegistry` made repeated
multiplies zero-retrace; this module is the front end that turns them into
a service: a stream of multiply requests (mixed families, mixed shapes)
moves through an explicit lifecycle and *no path hangs or silently
corrupts* —

::

    SUBMITTED ─ validate ──► ADMITTED ─ plan+price ──► PLANNED ──► EXECUTING
        │ queue full             │ deadline passed         │ breaker open /
        ▼                        ▼                         │ over budget
      SHED                    EXPIRED                      ▼
                                              DONE | DEGRADED | FAILED
                                              (requeue once on
                                               CapacityExhaustedError)

Admission uses the paper's sampled predictor as the cost model
(:mod:`repro.serve.admission`): the plan's predicted FLOP + nnz price the
request in bytes/seconds BEFORE any executor allocates, requests that
would overflow the device budget wait in a bounded queue (backpressure),
the queue sheds with a typed
:class:`~repro.core.errors.AdmissionRejectedError` when full, and a
deadline that passes while queued expires the request with
:class:`~repro.core.errors.DeadlineExceededError`.

Same-template requests batch into one dispatch wave through one cached
executor (zero retraces in steady state — compile-count pinned by
``tests/test_service.py``).  Executor failures surface as PR 6's typed
errors and drive a per-template circuit breaker (consecutive
:class:`~repro.core.errors.ShardFailureError` → OPEN → cooldown →
HALF_OPEN probe → reset); :class:`~repro.core.errors.CapacityExhaustedError`
requeues the request ONCE at an escalated
:class:`~repro.core.plan.RetryPolicy` before failing it with its
degradation ledger attached (``plan.stats()["degradations"]`` →
``request.stats["degradations"]``).

The service is a synchronous event loop (``submit`` / ``step`` /
``drain``) — every scheduling decision happens at a visible program point,
which is what makes the chaos soak (all 5 ``core.faults`` classes armed
over mixed traffic) deterministic.
"""
from __future__ import annotations

import dataclasses
import itertools
import time

import numpy as np

from repro.core import faults as faults_mod
from repro.core import plan as plan_mod
from repro.core import validate as validate_mod
from repro.core.errors import (AdmissionRejectedError, CapacityExhaustedError,
                               DeadlineExceededError, OperandValidationError,
                               PlanMismatchError, ShardFailureError,
                               SpgemmError)
from repro.serve import admission, queueing


# --------------------------------------------------------------------------- #
# Request lifecycle
# --------------------------------------------------------------------------- #
class RequestState:
    SUBMITTED = "SUBMITTED"
    ADMITTED = "ADMITTED"      # holds a bounded queue slot
    PLANNED = "PLANNED"        # plan built, cost estimate priced
    EXECUTING = "EXECUTING"
    DONE = "DONE"              # clean result
    DEGRADED = "DEGRADED"      # correct result via exact-symbolic fallback
    SHED = "SHED"              # queue full at submit
    FAILED = "FAILED"          # typed SpgemmError attached
    EXPIRED = "EXPIRED"        # deadline passed

    TERMINAL = frozenset({DONE, DEGRADED, SHED, FAILED, EXPIRED})


@dataclasses.dataclass(eq=False)
class Request:
    """The ticket ``submit`` returns; terminal state carries the result OR a
    typed error — never neither, never both silently wrong."""

    id: int
    a: object
    b: object
    deadline: float | None              # absolute service-clock time
    state: str = RequestState.SUBMITTED
    result: object = None               # host CSR on DONE/DEGRADED
    error: SpgemmError | None = None    # typed, on SHED/FAILED/EXPIRED
    estimate: admission.CostEstimate | None = None
    plan: object = None
    retry_policy: object = None         # escalated after 1st capacity failure
    attempts: int = 0
    submitted_at: float = 0.0
    finished_at: float | None = None
    history: list = dataclasses.field(default_factory=list)
    stats: dict = dataclasses.field(default_factory=dict)

    @property
    def done(self) -> bool:
        return self.state in RequestState.TERMINAL

    @property
    def latency(self) -> float | None:
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at

    def result_or_raise(self):
        """The service never raises mid-loop; callers collect here."""
        if not self.done:
            raise PlanMismatchError(
                f"request {self.id} is not terminal (state {self.state})",
                request=self.id)
        if self.error is not None:
            raise self.error
        return self.result


# --------------------------------------------------------------------------- #
# Per-template circuit breaker
# --------------------------------------------------------------------------- #
class CircuitBreaker:
    """CLOSED → (``threshold`` consecutive ShardFailureError) → OPEN →
    (``cooldown`` seconds) → HALF_OPEN probe → CLOSED on success, OPEN on
    failure.  One breaker per template: a family whose executor keeps dying
    fails fast instead of burning the queue, without touching other
    families' traffic."""

    CLOSED = "closed"
    OPEN = "open"
    HALF_OPEN = "half_open"

    def __init__(self, threshold: int, cooldown: float) -> None:
        self.threshold = int(threshold)
        self.cooldown = float(cooldown)
        self.state = self.CLOSED
        self.failures = 0
        self.opened_at: float | None = None
        self.last_error: SpgemmError | None = None
        self.trips = 0

    def allow(self, now: float) -> bool:
        if self.state == self.OPEN:
            if now - self.opened_at >= self.cooldown:
                self.state = self.HALF_OPEN      # admit ONE probe
                return True
            return False
        return True

    def record_success(self) -> None:
        self.state = self.CLOSED
        self.failures = 0
        self.last_error = None

    def record_failure(self, now: float, err: SpgemmError) -> None:
        self.failures += 1
        self.last_error = err
        if self.state == self.HALF_OPEN or self.failures >= self.threshold:
            self.state = self.OPEN
            self.opened_at = now
            self.trips += 1

    def stats(self) -> dict:
        return dict(state=self.state, failures=self.failures,
                    trips=self.trips)


# --------------------------------------------------------------------------- #
# Service configuration
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    queue_capacity: int = 64
    device_budget_bytes: int = 256 << 20
    default_deadline: float | None = None   # seconds from submit
    max_batch: int = 8
    safety: float = 1.3
    seed: int = 0
    pop_quant: bool = True
    template: str | None = "auto"           # "auto" | None
    n_panels: int = 0
    use_kernel: bool = False
    validate: bool = True
    breaker_threshold: int = 3
    breaker_cooldown: float = 1.0
    # base policy keeps the ladder short and surfaces exhaustion as a typed
    # CapacityExhaustedError; the escalated policy (one requeue later) turns
    # on the exact-symbolic fallback — guaranteed termination, DEGRADED
    retry_policy: plan_mod.RetryPolicy = plan_mod.RetryPolicy(
        rounds=1, exact_fallback=False, on_exhausted="raise")
    escalated_policy: plan_mod.RetryPolicy = plan_mod.RetryPolicy(
        rounds=2, growth=2.0, exact_fallback=True, on_exhausted="raise")


class SpgemmService:
    """The scheduler.  Owns its own :class:`~repro.core.plan.PlanCache` and
    :class:`~repro.core.plan.TemplateRegistry` so one service's compile
    state never aliases another's (or the session globals')."""

    def __init__(self, config: ServiceConfig | None = None, *,
                 clock=time.monotonic,
                 cache: plan_mod.PlanCache | None = None,
                 registry: plan_mod.TemplateRegistry | None = None) -> None:
        self.config = config if config is not None else ServiceConfig()
        self._clock = clock
        self._cache = cache if cache is not None else plan_mod.PlanCache()
        self._registry = (registry if registry is not None
                          else plan_mod.TemplateRegistry())
        self._queue = queueing.BoundedQueue(self.config.queue_capacity)
        self._budget = admission.MemoryBudget(self.config.device_budget_bytes)
        self._breakers: dict = {}
        self._ids = itertools.count()
        self.requests: list[Request] = []      # every ticket ever submitted
        self._counts = {s: 0 for s in RequestState.TERMINAL}
        self._requeues = 0
        self._waves = 0
        self._batched = 0

    # ---------------------------------------------------------------- state
    def _set_state(self, req: Request, state: str, now: float) -> None:
        req.state = state
        req.history.append((state, now))

    def _finish(self, req: Request, state: str, *,
                error: SpgemmError | None = None, result=None) -> None:
        now = self._clock()
        req.error = error
        req.result = result
        req.finished_at = now
        self._set_state(req, state, now)
        self._counts[state] += 1
        if req.plan is not None:
            # the degradation ledger + retry trail flow into the response
            # whether the request succeeded, degraded, or failed
            req.stats.setdefault("degradations",
                                 [dict(e) for e in req.plan.degradations])
            req.stats.setdefault("retries", int(req.plan.retries))
        if req.estimate is not None:
            req.stats.setdefault("estimate", req.estimate.stats())

    # --------------------------------------------------------------- submit
    def submit(self, a, b, *, deadline: float | None = None) -> Request:
        """Admit one request; never raises — the returned ticket is either
        queued (ADMITTED) or already terminal (SHED / FAILED)."""
        now = self._clock()
        rel = deadline if deadline is not None else self.config.default_deadline
        req = Request(id=next(self._ids), a=a, b=b,
                      deadline=(now + rel) if rel is not None else None,
                      submitted_at=now)
        req.history.append((RequestState.SUBMITTED, now))
        self.requests.append(req)
        if self.config.validate:
            # malformed operands are contained at the front door — a NaN
            # smuggled into values never reaches planning or the queue
            try:
                validate_mod.validate_pair(a, b)
            except SpgemmError as e:
                self._finish(req, RequestState.FAILED, error=e)
                return req
        try:
            self._queue.push(req)
        except AdmissionRejectedError as e:
            self._finish(req, RequestState.SHED, error=e)
            return req
        self._set_state(req, RequestState.ADMITTED, now)
        return req

    # ----------------------------------------------------------------- plan
    def _ensure_planned(self, req: Request, now: float) -> bool:
        if req.plan is not None:
            return True
        try:
            req.plan = plan_mod.plan_spgemm(
                req.a, req.b, safety=self.config.safety,
                seed=self.config.seed, pop_quant=self.config.pop_quant,
                template=self.config.template, registry=self._registry,
                n_panels=self.config.n_panels,
                use_kernel=self.config.use_kernel,
                retry_policy=(req.retry_policy if req.retry_policy is not None
                              else self.config.retry_policy),
                validate=False)            # validated at submit
        except SpgemmError as e:
            self._finish(req, RequestState.FAILED, error=e)
            return False
        req.estimate = admission.estimate_cost(req.plan)
        self._set_state(req, RequestState.PLANNED, now)
        return True

    def _breaker_for(self, req: Request) -> CircuitBreaker:
        tpl = getattr(req.plan, "_template", None)
        key = tpl if tpl is not None else req.plan.key
        if key not in self._breakers:
            self._breakers[key] = CircuitBreaker(
                self.config.breaker_threshold, self.config.breaker_cooldown)
        return self._breakers[key]

    # ----------------------------------------------------------------- step
    def _expire_queued(self, now: float) -> list[Request]:
        out = []
        for req in self._queue.expire(now):
            waited = now - req.submitted_at
            self._finish(req, RequestState.EXPIRED,
                         error=DeadlineExceededError(
                             f"request {req.id} deadline passed after "
                             f"{waited:.3f}s in queue", request=req.id,
                             deadline=req.deadline, observed=round(waited, 6)))
            out.append(req)
        return out

    def _gather_batch(self, head: Request, now: float,
                      finished: list[Request]) -> list[Request]:
        """Same-plan-key mates of ``head`` ride the same dispatch wave —
        one cached executor serves the whole batch with zero retraces.
        The memory budget bounds the wave (backpressure: non-fitting mates
        simply stay queued); non-matching requests keep their queue order."""
        batch = [head]
        self._budget.reserve(head.estimate)
        keep = []
        while len(self._queue):
            cand = self._queue.pop()
            if (len(batch) >= self.config.max_batch
                    or cand.a.shape != head.a.shape
                    or cand.b.shape != head.b.shape):
                keep.append(cand)
                continue
            if not self._ensure_planned(cand, now):
                finished.append(cand)          # typed plan-time failure
                continue
            if (cand.plan.key != head.plan.key
                    or not self._budget.fits_now(cand.estimate)):
                keep.append(cand)
                continue
            self._budget.reserve(cand.estimate)
            batch.append(cand)
        self._queue.restore(keep)              # passed-over mates keep order
        return batch

    def _execute_one(self, req: Request, breaker: CircuitBreaker) -> None:
        now = self._clock()
        if req.deadline is not None and req.deadline <= now:
            self._finish(req, RequestState.EXPIRED,
                         error=DeadlineExceededError(
                             f"request {req.id} deadline passed before "
                             "dispatch", request=req.id,
                             deadline=req.deadline))
            return
        self._set_state(req, RequestState.EXECUTING, now)
        try:
            out = plan_mod.execute(req.plan, req.a, req.b, cache=self._cache)
            c = plan_mod.reassemble(req.plan, out)
        except CapacityExhaustedError as e:
            if req.attempts == 0:
                # one requeue at the escalated policy (exact fallback on):
                # the retry is re-planned from scratch so the escalation is
                # visible in the plan's own ledger
                req.attempts = 1
                req.retry_policy = self.config.escalated_policy
                req.stats["first_error"] = str(e)
                req.plan = None
                req.estimate = None
                self._requeues += 1
                self._set_state(req, RequestState.ADMITTED, self._clock())
                self._queue.push_front(req)
            else:
                self._finish(req, RequestState.FAILED, error=e)
            return
        except ShardFailureError as e:
            breaker.record_failure(self._clock(), e)
            self._finish(req, RequestState.FAILED, error=e)
            return
        except SpgemmError as e:
            self._finish(req, RequestState.FAILED, error=e)
            return
        breaker.record_success()
        degraded = bool(req.plan.degradations)
        self._finish(req,
                     RequestState.DEGRADED if degraded else RequestState.DONE,
                     result=c)

    def step(self) -> list[Request]:
        """One scheduling wave: expire, pop, plan, admit, batch, execute.
        Returns the requests that reached a terminal state this wave."""
        now = self._clock()
        finished = self._expire_queued(now)
        head = self._queue.pop()
        if head is None:
            return finished
        if not self._ensure_planned(head, now):
            finished.append(head)
            return finished
        if not self._budget.fits_ever(head.estimate):
            # can NEVER be scheduled — terminal now, not an infinite requeue
            self._finish(head, RequestState.FAILED,
                         error=AdmissionRejectedError(
                             f"request {head.id} estimate "
                             f"{head.estimate.total_bytes} bytes exceeds the "
                             f"device budget {self._budget.total}",
                             reason="over_budget", request=head.id,
                             observed=int(head.estimate.total_bytes),
                             planned=int(self._budget.total)))
            finished.append(head)
            return finished
        breaker = self._breaker_for(head)
        if not breaker.allow(now):
            err = AdmissionRejectedError(
                f"circuit open for request {head.id}'s template "
                f"({breaker.failures} consecutive executor failures)",
                reason="circuit_open", request=head.id,
                observed=breaker.failures, planned=self.config.breaker_threshold)
            err.__cause__ = breaker.last_error
            self._finish(head, RequestState.FAILED, error=err)
            finished.append(head)
            return finished
        if breaker.state == CircuitBreaker.HALF_OPEN:
            batch = [head]                     # the probe rides alone
            self._budget.reserve(head.estimate)
        else:
            batch = self._gather_batch(head, now, finished)
        self._waves += 1
        self._batched += len(batch)
        for req in batch:
            est = req.estimate          # snapshot: the requeue path re-prices
            try:
                self._execute_one(req, breaker)
            finally:
                self._budget.release(est)
            if req.done:
                finished.append(req)
        return finished

    def drain(self, max_waves: int | None = None) -> list[Request]:
        """Run waves until the queue is empty.  Termination is structural —
        every pop either finishes or consumes the request's single escalated
        requeue — but a hard wave cap backstops 'no path hangs': exceeding
        it is a scheduler bug surfaced as a typed error, not a livelock."""
        if max_waves is None:
            max_waves = 4 * len(self.requests) + 16
        finished = []
        for _ in range(max_waves):
            if not len(self._queue):
                break
            finished.extend(self.step())
        if len(self._queue):
            raise PlanMismatchError(
                f"drain did not converge in {max_waves} waves "
                f"({len(self._queue)} requests still queued)",
                observed=len(self._queue))
        return finished

    # ---------------------------------------------------------------- stats
    def stats(self) -> dict:
        lat = [r.latency for r in self.requests if r.latency is not None]
        lat_stats = {}
        if lat:
            arr = np.asarray(lat, dtype=np.float64)
            lat_stats = dict(
                mean_s=round(float(arr.mean()), 6),
                p50_s=round(float(np.percentile(arr, 50)), 6),
                p99_s=round(float(np.percentile(arr, 99)), 6),
                max_s=round(float(arr.max()), 6))
        return dict(
            submitted=len(self.requests),
            terminal={s: self._counts[s]
                      for s in sorted(RequestState.TERMINAL)},
            in_flight=len(self.requests) - sum(self._counts.values()),
            requeues=self._requeues,
            waves=self._waves,
            batched_requests=self._batched,
            faults_armed=faults_mod.armed(),
            queue=self._queue.stats(),
            budget=self._budget.stats(),
            breakers=[b.stats() for b in self._breakers.values()],
            plan_cache=self._cache.stats(),
            templates=self._registry.stats(),
            latency=lat_stats,
        )
