"""Admission cost model for the SpGEMM service (DESIGN.md §10).

The paper's whole point — sample a sketch, predict the compression ratio,
size buffers *before* committing resources — is exactly what a serving
front end needs as its admission model: the sampled predictor prices a
multiply (predicted FLOP + predicted nnz → bytes + seconds) before a single
executor byte is allocated.  This module turns a plan's prediction into a
:class:`CostEstimate` with two contracts the property suite pins
(``tests/test_admission.py``):

* **monotone** — scaling the predicted per-row structure or the FLOP
  upper bound up never *decreases* the estimate (an admission controller
  that prices bigger work cheaper admits its way into OOM);
* **upper bound** — ``capacity_bytes`` dominates the bytes the planner
  actually allocates for the request's output buffers, on every suite
  family, with and without ``pop_quant``/templates/panels.  Admission
  against the estimate therefore admits against a *ceiling*, never a hope.

The bound mirrors the planner's own capacity rule
(:class:`repro.core.predictor.AllocationPlan`): per-row slots are
``min(ceil(structure·safety), flopr)``; every bucket's capacity is that
rule applied to a *subset* of rows, so the global max (align-8, pow2)
dominates each bucket's pow2 capacity, and pow2 population padding
inflates row counts by at most :data:`POP_PAD`.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import binning as binning_mod
from repro.core.errors import AdmissionRejectedError, PlanMismatchError

ENTRY_BYTES = 8      # one output/operand slot: int32 col + float32 val
RPT_BYTES = 4        # one CSR row pointer
POP_PAD = 2          # pow2 population padding inflates row counts ≤ 2×

# crude device model for the time estimate — serving needs *relative*
# prices for deadline triage, not a calibrated roofline (ROADMAP item 3
# replaces analytic lane costs with measured microbenchmarks)
EST_FLOPS = 5e9      # effective sparse FLOP/s
EST_BYTES_PER_S = 8e9


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """Per-request price: the admission controller's unit of account."""

    flop: int                # exact FLOP upper bound (Algorithm 1)
    predicted_nnz: float     # sampled-CR prediction (eq. 4)
    compression_ratio: float
    operand_bytes: int       # device uploads of A and B
    capacity_bytes: int      # ceiling on planned output buffers
    total_bytes: int         # operand + capacity: what admission reserves
    est_seconds: float

    def stats(self) -> dict:
        return dict(flop=int(self.flop),
                    predicted_nnz=round(float(self.predicted_nnz), 1),
                    compression_ratio=round(float(self.compression_ratio), 4),
                    operand_bytes=int(self.operand_bytes),
                    capacity_bytes=int(self.capacity_bytes),
                    total_bytes=int(self.total_bytes),
                    est_seconds=round(float(self.est_seconds), 6))


def capacity_bound_rows(structure, flopr, safety: float) -> int:
    """Pow2 per-row slot ceiling: dominates every bucket capacity the
    planner derives from (a subset of) the same prediction."""
    ps = np.asarray(structure, dtype=np.float64)
    fl = np.asarray(flopr, dtype=np.float64)
    if not ps.size:
        return 8
    per_row = np.minimum(np.ceil(ps * float(safety)), fl)
    cap = int(max(0.0, per_row.max(initial=0.0)))
    cap = max(8, ((cap + 7) // 8) * 8)
    return binning_mod.ceil_pow2(cap)


def estimate(nrows: int, structure, flopr, cr: float, *,
             nnz_a: int, nnz_b: int, nrows_b: int,
             safety: float = 1.3, n_panels: int = 0) -> CostEstimate:
    """Price a request from its prediction (no plan object required)."""
    fl = np.asarray(flopr, dtype=np.float64)
    total_flop = int(fl.sum())
    cap_rows = capacity_bound_rows(structure, fl, safety)
    units = max(1, int(n_panels))
    capacity_bytes = POP_PAD * int(nrows) * units * cap_rows * ENTRY_BYTES
    # pow2 operand caps ≤ 2×nnz (+ the 8-slot floor per panel slice)
    operand_bytes = (2 * max(8, int(nnz_a))
                     + 2 * int(nnz_b) + 8 * units) * ENTRY_BYTES \
        + (int(nrows) + 1 + (int(nrows_b) + 1) * units) * RPT_BYTES
    total_bytes = capacity_bytes + operand_bytes
    est_seconds = total_flop / EST_FLOPS + total_bytes / EST_BYTES_PER_S
    ps = np.asarray(structure, dtype=np.float64)
    return CostEstimate(
        flop=total_flop,
        predicted_nnz=float(ps.sum()) if ps.size else 0.0,
        compression_ratio=float(cr),
        operand_bytes=int(operand_bytes),
        capacity_bytes=int(capacity_bytes),
        total_bytes=int(total_bytes),
        est_seconds=float(est_seconds))


def estimate_cost(plan) -> CostEstimate:
    """Price a planned request from the plan's own sampled prediction —
    the admission path of :class:`repro.serve.spgemm_service.SpgemmService`
    (plan host-side first, admit against the ceiling, only then execute).

    The formula bound already dominates the plan's own capacities; a
    template grown by OTHER family members can exceed the member-local
    formula, so the ceiling is maxed with the exactly-planned bytes."""
    est = estimate(
        plan.shape_a[0], plan.structure, plan.flopr,
        plan.compression_ratio, nnz_a=plan.cap_a, nnz_b=plan.cap_b,
        nrows_b=plan.shape_b[0], safety=plan.safety,
        n_panels=plan.n_panels)
    actual = planned_bytes(plan)
    if actual > est.capacity_bytes:
        est = dataclasses.replace(
            est, capacity_bytes=actual,
            total_bytes=actual + est.operand_bytes)
    return est


def planned_bytes(plan) -> int:
    """The bytes the planner ACTUALLY allocated for output buffers — what
    ``CostEstimate.capacity_bytes`` must dominate (property-pinned)."""
    if plan.n_panels and not plan.distributed:
        pops = plan.local_populations()
        return int(sum(int(pop) * int(c) * ENTRY_BYTES
                       for pop, row in zip(pops, plan.panel_caps)
                       for c in row))
    if plan.distributed:
        return int(plan.shard_slots()) * plan.num_shards * ENTRY_BYTES
    return int(sum(int(pop) * int(c) * ENTRY_BYTES
                   for pop, c in zip(plan.local_populations(),
                                     plan.alloc.bucket_capacities)))


class MemoryBudget:
    """Byte ledger for admission: reserve at dispatch, release at terminal.

    The service is synchronous per dispatch wave, so the ledger's job is
    bounding the BATCH (how many same-template requests ride one wave) and
    rejecting requests that could never fit — not racing concurrent
    executors."""

    def __init__(self, total_bytes: int) -> None:
        if int(total_bytes) <= 0:
            raise PlanMismatchError(
                f"device budget must be positive, got {total_bytes}")
        self.total = int(total_bytes)
        self.reserved = 0

    @property
    def remaining(self) -> int:
        return self.total - self.reserved

    def fits_ever(self, est: CostEstimate) -> bool:
        return est.total_bytes <= self.total

    def fits_now(self, est: CostEstimate) -> bool:
        return est.total_bytes <= self.remaining

    def reserve(self, est: CostEstimate) -> None:
        if not self.fits_now(est):
            raise AdmissionRejectedError(
                f"cost estimate {est.total_bytes} bytes exceeds remaining "
                f"budget {self.remaining}", reason="budget",
                observed=int(est.total_bytes), planned=int(self.remaining))
        self.reserved += est.total_bytes

    def release(self, est: CostEstimate) -> None:
        self.reserved = max(0, self.reserved - est.total_bytes)

    def stats(self) -> dict:
        return dict(total=self.total, reserved=self.reserved,
                    remaining=self.remaining)
