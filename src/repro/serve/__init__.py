# Serving layer: the SpGEMM request scheduler (DESIGN.md §10) plus the
# transformer inference engine demo.  Lazy imports keep `from repro.serve
# import admission` from dragging jax tracing machinery in.


def __getattr__(name):
    if name in ("SpgemmService", "ServiceConfig", "Request", "RequestState",
                "CircuitBreaker"):
        from . import spgemm_service as _svc
        return getattr(_svc, name)
    if name in ("CostEstimate", "MemoryBudget", "estimate", "estimate_cost",
                "planned_bytes", "capacity_bound_rows"):
        from . import admission as _adm
        return getattr(_adm, name)
    if name == "BoundedQueue":
        from . import queueing as _q
        return _q.BoundedQueue
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
