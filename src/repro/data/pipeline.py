"""Deterministic synthetic token pipeline (per-host sharded, restartable).

Production shape: each host generates only its shard of the global batch
(``host_slice``), the stream is a pure function of (seed, step) so restart
from a checkpointed step reproduces the exact batch sequence (no data-loader
state files), and the generator models a power-law unigram distribution with
local n-gram structure so cross-entropy actually *decreases* during the e2e
example runs (a uniform stream cannot be learned).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.1
    ngram_period: int = 8     # deterministic local structure


class SyntheticLM:
    """batch(step) → dict(tokens, labels, positions), pure in (seed, step)."""

    def __init__(self, cfg: DataConfig, host_index: int = 0, host_count: int = 1):
        assert cfg.global_batch % host_count == 0
        self.cfg = cfg
        self.host_index = host_index
        self.host_count = host_count
        self.local_batch = cfg.global_batch // host_count
        # fixed unigram table (shared across hosts)
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_alpha)
        self.probs = probs / probs.sum()
        # per-token deterministic successor table → learnable bigram structure
        self.successor = rng.permutation(cfg.vocab_size)

    def batch(self, step: int) -> dict:
        c = self.cfg
        rng = np.random.default_rng(
            (c.seed * 1_000_003 + step) * 65_537 + self.host_index)
        draws = rng.choice(c.vocab_size, size=(self.local_batch, c.seq_len + 1),
                           p=self.probs)
        # every `ngram_period`-th position is the deterministic successor of
        # the previous token — a learnable signal
        out = draws.copy()
        idx = np.arange(1, c.seq_len + 1)
        mask = (idx % c.ngram_period) == 0
        out[:, idx[mask]] = self.successor[out[:, idx[mask] - 1]]
        tokens = out[:, :-1].astype(np.int32)
        labels = out[:, 1:].astype(np.int32)
        positions = np.broadcast_to(
            np.arange(c.seq_len, dtype=np.int32)[None], tokens.shape)
        return {"tokens": tokens, "labels": labels, "positions": positions.copy()}
