"""Declarative parameter schema: one source of truth for shapes, logical
sharding axes, and initialization.

Every model builds a nested dict of ``PSpec`` leaves.  From the same tree we
derive (a) materialized params (``init_params``), (b) ShapeDtypeStructs for
the dry-run (``abstract_params``), and (c) ``PartitionSpec`` trees via the
logical-axis rules in ``repro.models.sharding``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PSpec:
    """A parameter leaf: shape + logical axes + init style."""
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]      # logical axis names, len == len(shape)
    init: str = "normal"              # "normal" | "zeros" | "ones" | "embed"
    scale: float | None = None        # fan-in override for "normal"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _leaf_init(spec: PSpec, key: jax.Array, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "embed":
        return (jax.random.normal(key, spec.shape) * 0.02).astype(dtype)
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    scale = spec.scale if spec.scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape) * scale).astype(dtype)


def is_pspec(x: Any) -> bool:
    return isinstance(x, PSpec)


def init_params(schema: dict, key: jax.Array, dtype=jnp.float32):
    """Materialize a schema tree into arrays (deterministic in ``key``)."""
    leaves, treedef = jax.tree_util.tree_flatten(schema, is_leaf=is_pspec)
    keys = jax.random.split(key, len(leaves))
    arrs = [_leaf_init(l, k, dtype) for l, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, arrs)


def abstract_params(schema: dict, dtype=jnp.float32):
    """ShapeDtypeStruct tree — the dry-run path (no allocation)."""
    return jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, dtype), schema, is_leaf=is_pspec)


def logical_axes(schema: dict):
    """Tree of logical-axis tuples (same structure as params)."""
    return jax.tree_util.tree_map(lambda l: l.axes, schema, is_leaf=is_pspec)


def param_count(schema: dict) -> int:
    leaves = jax.tree_util.tree_leaves(schema, is_leaf=is_pspec)
    return int(sum(int(np.prod(l.shape)) for l in leaves))


def stack_layers(layer_schema: dict, n: int) -> dict:
    """Prepend a scan ('layers') axis to every leaf — stacked-layer params."""
    return jax.tree_util.tree_map(
        lambda l: PSpec((n,) + l.shape, ("layers",) + l.axes, l.init, l.scale),
        layer_schema, is_leaf=is_pspec)
