"""Unified model stack for all assigned families.

Layers are grouped into *segments* of identical repeating period (e.g.
deepseek-v3 = [3×dense] + [58×moe]; xlstm = 3×(mlstm,mlstm,mlstm,slstm));
each segment's params are stacked over repeats and applied with
``lax.scan`` — the HLO stays O(period), not O(num_layers), which keeps the
512-device dry-run compile tractable and lets XLA's scheduler overlap each
layer's collectives with the next layer's compute.

Public API:
  build_schema(cfg, mesh_model)                → PSpec tree
  forward(params, cfg, batch, ...)             → (logits, Aux)     [train]
  init_cache / prefill / decode_step           → serving
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (norm_schema, apply_norm, mlp_schema, apply_mlp,
                     embed_schema, embed_tokens, lm_head)
from .schema import PSpec, stack_layers


# --------------------------------------------------------------------------- #
# segment planning
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class SegmentPlan:
    kinds: tuple[str, ...]   # block kinds within one period
    repeats: int             # scan length
    layer_offset: int        # global index of the segment's first layer


def segment_plan(cfg) -> list[SegmentPlan]:
    if cfg.block_pattern:
        period = tuple(cfg.block_pattern)
        assert cfg.num_layers % len(period) == 0, (cfg.num_layers, period)
        return [SegmentPlan(period, cfg.num_layers // len(period), 0)]
    if cfg.moe_num_experts:
        segs = []
        off = 0
        if cfg.moe_dense_layers:
            segs.append(SegmentPlan(("attn",), cfg.moe_dense_layers, 0))
            off = cfg.moe_dense_layers
        segs.append(SegmentPlan(("moe",), cfg.num_layers - off, off))
        return segs
    return [SegmentPlan(("attn",), cfg.num_layers, 0)]


# --------------------------------------------------------------------------- #
# per-kind block schemas
# --------------------------------------------------------------------------- #
def _block_schema(cfg, kind: str, mesh_model: int) -> dict:
    if kind == "attn":
        sch = {"ln1": norm_schema(cfg),
               "attn": attn_mod.attention_schema(cfg, mesh_model)}
        if cfg.d_ff:
            sch["ln2"] = norm_schema(cfg)
            sch["mlp"] = mlp_schema(cfg)
        return sch
    if kind == "moe":
        return {"ln1": norm_schema(cfg),
                "attn": attn_mod.attention_schema(cfg, mesh_model),
                "ln2": norm_schema(cfg),
                "moe": moe_mod.moe_schema(cfg)}
    if kind == "mamba":
        return {"ln1": norm_schema(cfg), "mamba": ssm_mod.mamba_schema(cfg)}
    if kind == "mlstm":
        return {"ln1": norm_schema(cfg), "mlstm": ssm_mod.mlstm_schema(cfg)}
    if kind == "slstm":
        return {"ln1": norm_schema(cfg), "slstm": ssm_mod.slstm_schema(cfg)}
    raise ValueError(kind)


def build_schema(cfg, mesh_model: int = 1) -> dict:
    pv = cfg.padded_vocab()
    sch: dict[str, Any] = {"embed": embed_schema(cfg, pv)}
    for si, seg in enumerate(segment_plan(cfg)):
        period = {f"pos{j}": _block_schema(cfg, k, mesh_model)
                  for j, k in enumerate(seg.kinds)}
        sch[f"seg{si}"] = stack_layers(period, seg.repeats)
    if cfg.attn_every:  # zamba2 shared attention+MLP block (one weight set)
        sch["shared_attn"] = {
            "ln1": norm_schema(cfg),
            "attn": attn_mod.gqa_schema(cfg, mesh_model),
            "ln2": norm_schema(cfg),
            "mlp": mlp_schema(cfg),
        }
    if cfg.is_encoder_decoder:
        enc_period = {"pos0": _block_schema(cfg, "attn", mesh_model)}
        sch["encoder"] = stack_layers(enc_period, cfg.num_encoder_layers)
        sch["enc_norm"] = norm_schema(cfg)
        # decoder blocks get cross attention
        cross_period = {"pos0": {"ln_x": norm_schema(cfg),
                                 "cross": attn_mod.cross_schema(cfg, mesh_model)}}
        sch["cross"] = stack_layers(cross_period, cfg.num_layers)
    if cfg.mtp_heads:  # deepseek multi-token prediction module
        sch["mtp"] = {
            "proj": PSpec((2 * cfg.d_model, cfg.d_model), (None, "embed")),
            "block": _block_schema(cfg, "attn", mesh_model),
            "norm": norm_schema(cfg),
        }
    sch["final_norm"] = norm_schema(cfg)
    return sch


# --------------------------------------------------------------------------- #
# block application (training / full-seq)
# --------------------------------------------------------------------------- #
class Aux(NamedTuple):
    moe_lb: jax.Array
    moe_z: jax.Array
    moe_dropped: jax.Array


def _zero_aux() -> Aux:
    return Aux(jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
               jnp.zeros((), jnp.float32))


def _apply_block(p, cfg, kind, x, positions, aux: Aux, *, causal=True,
                 capacity=None):
    if kind in ("attn", "moe"):
        h = apply_norm(p["ln1"], x)
        if cfg.attention_type == "mla":
            a = attn_mod.mla_forward(p["attn"], cfg, h, positions, causal=causal)
        else:
            a = attn_mod.gqa_forward(p["attn"], cfg, h, positions, causal=causal)
        x = x + a
        if kind == "moe":
            h = apply_norm(p["ln2"], x)
            y, maux = moe_mod.apply_moe(p["moe"], cfg, h, capacity=capacity)
            x = x + y
            aux = Aux(aux.moe_lb + maux.load_balance_loss,
                      aux.moe_z + maux.router_z_loss,
                      aux.moe_dropped + maux.dropped_fraction)
        elif cfg.d_ff:
            h = apply_norm(p["ln2"], x)
            x = x + apply_mlp(p["mlp"], h)
        return x, aux
    if kind == "mamba":
        return x + ssm_mod.mamba_forward(p["mamba"], cfg, apply_norm(p["ln1"], x)), aux
    if kind == "mlstm":
        return x + ssm_mod.mlstm_forward(p["mlstm"], cfg, apply_norm(p["ln1"], x)), aux
    if kind == "slstm":
        return x + ssm_mod.slstm_forward(p["slstm"], cfg, apply_norm(p["ln1"], x)), aux
    raise ValueError(kind)


def _apply_shared_attn(p, cfg, x, positions, *, window: int = 0):
    h = apply_norm(p["ln1"], x)
    x = x + attn_mod.gqa_forward(p["attn"], cfg, h, positions, causal=True,
                                 window=window)
    h = apply_norm(p["ln2"], x)
    return x + apply_mlp(p["mlp"], h)


def _remat_wrap(cfg, fn):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.nothing_saveable if cfg.remat == "full"
              else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=policy)


def _run_segments(params, cfg, x, positions, aux, *, capacity, causal=True):
    from .sharding import constrain_batch
    for si, seg in enumerate(segment_plan(cfg)):
        seg_params = params[f"seg{si}"]

        def body(carry, inp):
            xx, aux_c = carry
            layer_p, rep_idx = inp
            xx = constrain_batch(
                xx, batch_over_model=not cfg.tensor_parallel)  # pin saved stack
            for j, kind in enumerate(seg.kinds):
                xx, aux_c = _apply_block(layer_p[f"pos{j}"], cfg, kind, xx,
                                         positions, aux_c, causal=causal,
                                         capacity=capacity)
                if cfg.attn_every:
                    gidx = seg.layer_offset + rep_idx * len(seg.kinds) + j
                    xx = jax.lax.cond(
                        (gidx + 1) % cfg.attn_every == 0,
                        lambda v: _apply_shared_attn(
                            params["shared_attn"], cfg, v, positions),
                        lambda v: v, xx)
            return (xx, aux_c), None

        body = _remat_wrap(cfg, body)
        (x, aux), _ = jax.lax.scan(
            body, (x, aux), (seg_params, jnp.arange(seg.repeats)))
    return x, aux


# --------------------------------------------------------------------------- #
# encoder (whisper)
# --------------------------------------------------------------------------- #
def _run_encoder(params, cfg, frame_embeds):
    from .sharding import constrain_batch
    x = frame_embeds
    pos = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32)[None],
                           x.shape[:2])

    def body(xx, layer_p):
        xx = constrain_batch(xx, batch_over_model=not cfg.tensor_parallel)
        xx, _ = _apply_block(layer_p["pos0"], cfg, "attn", xx, pos,
                             _zero_aux(), causal=False)
        return xx, None

    x, _ = jax.lax.scan(_remat_wrap(cfg, body), x, params["encoder"])
    return apply_norm(params["enc_norm"], x)


def _run_cross(params, cfg, x, enc_out, layer_slice):
    """Apply the stacked cross-attention for decoder layer ``layer_slice``."""
    p = jax.tree_util.tree_map(lambda a: a[layer_slice], params["cross"])
    h = apply_norm(p["pos0"]["ln_x"], x)
    return x + attn_mod.cross_forward(p["pos0"]["cross"], cfg, h, enc_out)


# --------------------------------------------------------------------------- #
# training / full-sequence forward
# --------------------------------------------------------------------------- #
def forward(params, cfg, batch, *, capacity: int | None = None):
    """batch: tokens (B,S) [+ positions, patch_embeds, frame_embeds].

    Returns (logits (B,S,V_padded) fp32, Aux).
    """
    dtype = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(
            jnp.arange(tokens.shape[1], dtype=jnp.int32)[None], tokens.shape)
    from .sharding import constrain_batch
    x = constrain_batch(embed_tokens(params["embed"], tokens, dtype),
                        batch_over_model=not cfg.tensor_parallel)
    if cfg.frontend == "vision_stub" and "patch_embeds" in batch:
        # early fusion: precomputed patch embeddings replace the first P slots
        pe = batch["patch_embeds"].astype(dtype)
        x = jnp.concatenate([pe, x[:, pe.shape[1]:]], axis=1)
    if capacity is None and cfg.moe_num_experts:
        # per-group (= per batch row) capacity
        capacity = moe_mod.default_capacity(cfg, tokens.shape[1])
    aux = _zero_aux()

    if cfg.is_encoder_decoder:
        enc_out = _run_encoder(params, cfg, batch["frame_embeds"].astype(dtype))
        # decoder: interleave self-attn blocks with cross-attn — run per layer
        seg = segment_plan(cfg)[0]

        def body(carry, inp):
            xx, aux_c = carry
            layer_p, cross_p, rep_idx = inp
            xx = constrain_batch(
                xx, batch_over_model=not cfg.tensor_parallel)
            xx, aux_c = _apply_block(layer_p["pos0"], cfg, "attn", xx,
                                     positions, aux_c, causal=True,
                                     capacity=capacity)
            h = apply_norm(cross_p["pos0"]["ln_x"], xx)
            xx = xx + attn_mod.cross_forward(cross_p["pos0"]["cross"], cfg, h,
                                             enc_out)
            return (xx, aux_c), None

        (x, aux), _ = jax.lax.scan(
            _remat_wrap(cfg, body), (x, aux),
            (params["seg0"], params["cross"], jnp.arange(seg.repeats)))
    else:
        x, aux = _run_segments(params, cfg, x, positions, aux,
                               capacity=capacity)

    x = apply_norm(params["final_norm"], x)
    # vocab stays `model`-sharded through the CE (logsumexp → all-reduce)
    logits = constrain_batch(
        lm_head(params["embed"], x),
        sharded_tail={2: "model"} if cfg.tensor_parallel else None,
        batch_over_model=not cfg.tensor_parallel)

    if cfg.mtp_heads:  # deepseek MTP: predict t+2 from [h_t ; emb(t+1)]
        emb_next = embed_tokens(params["embed"],
                                jnp.roll(tokens, -1, axis=1), dtype)
        h_mtp = jnp.concatenate([x.astype(dtype), emb_next], axis=-1)
        h_mtp = h_mtp @ params["mtp"]["proj"].astype(dtype)
        h_mtp, _ = _apply_block(params["mtp"]["block"], cfg, "attn", h_mtp,
                                positions, _zero_aux(), capacity=capacity)
        h_mtp = apply_norm(params["mtp"]["norm"], h_mtp)
        mtp_logits = lm_head(params["embed"], h_mtp)
        return logits, aux, mtp_logits
    return logits, aux, None


# --------------------------------------------------------------------------- #
# serving: cache init / prefill / decode
# --------------------------------------------------------------------------- #
def _block_cache(cfg, kind, batch, max_len, dtype, mesh_model=1):
    if kind in ("attn", "moe"):
        if cfg.attention_type == "mla":
            return attn_mod.init_mla_cache(cfg, batch, max_len, dtype)
        return attn_mod.init_gqa_cache(cfg, batch, max_len, dtype, mesh_model)
    if kind == "mamba":
        return ssm_mod.init_mamba_cache(cfg, batch, dtype)
    if kind == "mlstm":
        return ssm_mod.init_mlstm_cache(cfg, batch, dtype)
    if kind == "slstm":
        return ssm_mod.init_slstm_cache(cfg, batch, dtype)
    raise ValueError(kind)


def init_cache(cfg, batch: int, max_len: int, mesh_model: int = 1):
    """Stacked-over-repeats cache pytree mirroring the segment structure."""
    dtype = jnp.dtype(cfg.dtype)
    cache: dict[str, Any] = {}
    eff_len = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    for si, seg in enumerate(segment_plan(cfg)):
        period = {}
        for j, kind in enumerate(seg.kinds):
            c = _block_cache(cfg, kind, batch, eff_len if kind in ("attn", "moe")
                             else max_len, dtype, mesh_model)
            period[f"pos{j}"] = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (seg.repeats,) + a.shape), c)
        cache[f"seg{si}"] = period
    if cfg.attn_every:
        n_shared = sum(1 for i in range(cfg.num_layers)
                       if (i + 1) % cfg.attn_every == 0)
        c = attn_mod.init_gqa_cache(cfg, batch, eff_len, dtype, mesh_model)
        cache["shared_attn"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (n_shared,) + a.shape), c)
    return cache


def _decode_block(p, cfg, kind, x, positions, cache, cur_len, *, window=0):
    if kind in ("attn", "moe"):
        h = apply_norm(p["ln1"], x)
        if cfg.attention_type == "mla":
            a, cache = attn_mod.mla_decode(p["attn"], cfg, h, positions, cache,
                                           cur_len)
        else:
            a, cache = attn_mod.gqa_decode(p["attn"], cfg, h, positions, cache,
                                           cur_len, window=window)
        x = x + a
        if kind == "moe":
            h = apply_norm(p["ln2"], x)
            # decode: groups of one token → k distinct experts, ≤1 slot each
            y, _ = moe_mod.apply_moe(p["moe"], cfg, h, capacity=4)
            x = x + y
        elif cfg.d_ff:
            x = x + apply_mlp(p["mlp"], apply_norm(p["ln2"], x))
        return x, cache
    if kind == "mamba":
        y, cache = ssm_mod.mamba_decode(p["mamba"], cfg,
                                        apply_norm(p["ln1"], x), cache)
        return x + y, cache
    if kind == "mlstm":
        y, cache = ssm_mod.mlstm_decode(p["mlstm"], cfg,
                                        apply_norm(p["ln1"], x), cache)
        return x + y, cache
    if kind == "slstm":
        y, cache = ssm_mod.slstm_decode(p["slstm"], cfg,
                                        apply_norm(p["ln1"], x), cache)
        return x + y, cache
    raise ValueError(kind)


def decode_step(params, cfg, tokens, cache, cur_len, *, enc_out=None):
    """One-token decode.  tokens (B, 1); cur_len scalar int32 (current cache
    fill).  Returns (logits (B,1,V) fp32, new cache)."""
    dtype = jnp.dtype(cfg.dtype)
    b = tokens.shape[0]
    positions = jnp.broadcast_to(cur_len.astype(jnp.int32), (b, 1))
    x = embed_tokens(params["embed"], tokens, dtype)
    window = cfg.sliding_window
    shared_ct = 0
    new_cache: dict[str, Any] = {}
    for si, seg in enumerate(segment_plan(cfg)):
        seg_params = params[f"seg{si}"]
        seg_cache = cache[f"seg{si}"]
        shared_p = params.get("shared_attn")
        use_shared = cfg.attn_every and shared_p is not None

        if use_shared or cfg.is_encoder_decoder:
            # unrolled per-repeat (shared-attn interleave / cross attention)
            period_caches = []
            for r in range(seg.repeats):
                layer_p = jax.tree_util.tree_map(lambda a: a[r], seg_params)
                rep_cache = jax.tree_util.tree_map(lambda a: a[r], seg_cache)
                pc = {}
                for j, kind in enumerate(seg.kinds):
                    x, c = _decode_block(layer_p[f"pos{j}"], cfg, kind, x,
                                         positions, rep_cache[f"pos{j}"],
                                         cur_len, window=window)
                    pc[f"pos{j}"] = c
                    gidx = seg.layer_offset + r * len(seg.kinds) + j
                    if use_shared and (gidx + 1) % cfg.attn_every == 0:
                        sc = jax.tree_util.tree_map(
                            lambda a: a[shared_ct], cache["shared_attn"])
                        h = apply_norm(shared_p["ln1"], x)
                        a, sc = attn_mod.gqa_decode(shared_p["attn"], cfg, h,
                                                    positions, sc, cur_len,
                                                    window=window)
                        x = x + a
                        x = x + apply_mlp(shared_p["mlp"],
                                          apply_norm(shared_p["ln2"], x))
                        new_cache.setdefault("shared_attn_list", []).append(sc)
                        shared_ct += 1
                    if cfg.is_encoder_decoder and enc_out is not None:
                        cross_p = jax.tree_util.tree_map(
                            lambda a: a[r], params["cross"])
                        h = apply_norm(cross_p["pos0"]["ln_x"], x)
                        x = x + attn_mod.cross_forward(
                            cross_p["pos0"]["cross"], cfg, h, enc_out)
                period_caches.append(pc)
            new_cache[f"seg{si}"] = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *period_caches)
        else:
            def body(carry, inp):
                xx, _ = carry
                layer_p, rep_cache = inp
                pc = {}
                for j, kind in enumerate(seg.kinds):
                    xx, c = _decode_block(layer_p[f"pos{j}"], cfg, kind, xx,
                                          positions, rep_cache[f"pos{j}"],
                                          cur_len, window=window)
                    pc[f"pos{j}"] = c
                return (xx, carry[1]), pc

            (x, _), stacked = jax.lax.scan(body, (x, jnp.zeros(())),
                                           (seg_params, seg_cache))
            new_cache[f"seg{si}"] = stacked
    if "shared_attn_list" in new_cache:
        lst = new_cache.pop("shared_attn_list")
        new_cache["shared_attn"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *lst)
    x = apply_norm(params["final_norm"], x)
    return lm_head(params["embed"], x), new_cache
