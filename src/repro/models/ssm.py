"""SSM / recurrent blocks: Mamba2 (zamba2), mLSTM + sLSTM (xlstm).

One chunked SSD scan (``ssd_chunk_scan``) serves both Mamba2 and mLSTM — they
share the state-space structure  S_t = a_t·S_{t-1} + dt_t·(B_t ⊗ x_t),
y_t = C_t·S_t: Mamba2 sets a = exp(dt·A); mLSTM sets (B, C, dt, a) =
(k, q, i-gate, f-gate) with an extra normalizer channel.  The scan processes
``chunk``-sized blocks: quadratic intra-chunk attention-form (stable — decay
differences only inside a chunk) + sequential inter-chunk state carry via
``lax.scan``, keeping peak memory at O(B·L²·H) per chunk instead of O(B·S²).

Decode paths are exact single-step recurrences over the carried state, so
long_500k decode is O(1) per token per layer (DESIGN §6).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .schema import PSpec
from .layers import apply_norm

NEG_INF = -1e30


# --------------------------------------------------------------------------- #
# shared chunked SSD scan
# --------------------------------------------------------------------------- #
def ssd_chunk_scan(xh, dt, bm, cm, da, chunk: int, state0):
    """xh (B,S,H,P), dt (B,S,H), bm/cm (B,S,H,N), da (B,S,H) = log-decay ≤ 0.

    Returns (y (B,S,H,P) fp32, final_state (B,H,N,P) fp32).
    """
    b, s, h, p = xh.shape
    n = bm.shape[-1]
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0)))

    def rs(t):  # (B, nc, L, ...) → scan over nc
        return t.reshape((b, nc) + (chunk,) + t.shape[2:]).swapaxes(0, 1)

    xc, dtc, bc, cc, dac = rs(xh.astype(jnp.float32)), rs(dt.astype(jnp.float32)), \
        rs(bm.astype(jnp.float32)), rs(cm.astype(jnp.float32)), rs(da.astype(jnp.float32))

    def step(state, inp):
        x1, dt1, b1, c1, a1 = inp                       # (B,L,H,P) etc.
        cum = jnp.cumsum(a1, axis=1)                    # (B,L,H)
        # intra-chunk: decay[l,m] = exp(cum_l - cum_m), m ≤ l  (stable in-chunk)
        diff = cum[:, :, None, :] - cum[:, None, :, :]  # (B,L,M,H)
        lm = jnp.tril(jnp.ones((chunk, chunk), bool))
        decay = jnp.exp(jnp.where(lm[None, :, :, None], diff, NEG_INF))
        cb = jnp.einsum("blhn,bmhn->blmh", c1, b1)      # (B,L,M,H)
        dtx = dt1[..., None] * x1                       # (B,L,H,P)
        y_intra = jnp.einsum("blmh,bmhp->blhp", cb * decay, dtx)
        # inter-chunk: carried state read
        y_inter = jnp.einsum("blhn,bhnp->blhp", c1, state) * \
            jnp.exp(cum)[..., None]
        # state update
        last = cum[:, -1]                               # (B,H)
        w = jnp.exp(last[:, None, :] - cum)             # (B,L,H)
        s_new = state * jnp.exp(last)[:, :, None, None] + \
            jnp.einsum("blhn,blh,blhp->bhnp", b1, w, dtx)
        return s_new, y_intra + y_inter

    state_f, ys = jax.lax.scan(step, state0.astype(jnp.float32),
                               (xc, dtc, bc, cc, dac))
    y = ys.swapaxes(0, 1).reshape(b, nc * chunk, h, p)[:, :s]
    return y, state_f


def ssd_decode_step(state, x1, dt1, b1, c1, a1):
    """Single-token recurrence.  x1 (B,H,P), dt1/a1 (B,H), b1/c1 (B,H,N)."""
    decay = jnp.exp(a1.astype(jnp.float32))
    s_new = state * decay[:, :, None, None] + jnp.einsum(
        "bhn,bh,bhp->bhnp", b1.astype(jnp.float32),
        dt1.astype(jnp.float32), x1.astype(jnp.float32))
    y = jnp.einsum("bhn,bhnp->bhp", c1.astype(jnp.float32), s_new)
    return y, s_new


# --------------------------------------------------------------------------- #
# causal depthwise conv (width W) + state for decode
# --------------------------------------------------------------------------- #
def causal_conv(x, w, b):
    """x (B,S,C), w (W,C) depthwise, left-padded causal."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(xp[:, i: i + x.shape[1]] * w[i][None, None, :]
              for i in range(width))
    return out + b[None, None, :]


def causal_conv_step(conv_state, x1, w, b):
    """conv_state (B, W-1, C); x1 (B, C) → (y (B,C), new_state)."""
    width = w.shape[0]
    full = jnp.concatenate([conv_state, x1[:, None, :]], axis=1)  # (B, W, C)
    y = jnp.einsum("bwc,wc->bc", full, w) + b[None, :]
    return y, full[:, 1:]


# --------------------------------------------------------------------------- #
# Mamba2 block
# --------------------------------------------------------------------------- #
CONV_W = 4


class MambaCache(NamedTuple):
    state: jax.Array       # (B, H, N, P) fp32
    conv: jax.Array        # (B, CONV_W-1, di + 2N)


def mamba_dims(cfg):
    di = cfg.ssm_expand * cfg.d_model
    p = cfg.ssm_head_dim
    h = di // p
    n = cfg.ssm_state_dim
    return di, h, p, n


def mamba_schema(cfg) -> dict:
    d = cfg.d_model
    di, h, p, n = mamba_dims(cfg)
    cw = di + 2 * n
    return {
        "w_in": PSpec((d, 2 * di + 2 * n + h), ("embed", "ssm_inner")),
        "conv_w": PSpec((CONV_W, cw), (None, None), "normal", 0.2),
        "conv_b": PSpec((cw,), (None,), "zeros"),
        "a_log": PSpec((h,), (None,), "zeros"),
        "dt_bias": PSpec((h,), (None,), "zeros"),
        "d_skip": PSpec((h,), (None,), "ones"),
        "norm": {"scale": PSpec((di,), ("ssm_inner",), "ones")},
        "w_out": PSpec((di, d), ("ssm_inner", "embed")),
    }


def _mamba_proj(p, cfg, x):
    di, h, _, n = mamba_dims(cfg)
    z_xbc_dt = x @ p["w_in"].astype(x.dtype)
    z = z_xbc_dt[..., :di]
    xbc = z_xbc_dt[..., di: 2 * di + 2 * n]
    dt_raw = z_xbc_dt[..., 2 * di + 2 * n:]
    return z, xbc, dt_raw


def _mamba_post(p, cfg, y, z, x_dtype):
    di, h, pp, _ = mamba_dims(cfg)
    b = y.shape[0]
    y = y.reshape(y.shape[:-2] + (di,)).astype(x_dtype)
    y = apply_norm(p["norm"], y * jax.nn.silu(z))
    return y @ p["w_out"].astype(x_dtype)


def mamba_forward(p, cfg, x):
    """x (B,S,d) → (B,S,d)."""
    di, h, pp, n = mamba_dims(cfg)
    z, xbc, dt_raw = _mamba_proj(p, cfg, x)
    xbc = jax.nn.silu(causal_conv(xbc, p["conv_w"].astype(x.dtype),
                                  p["conv_b"].astype(x.dtype)))
    xs, bmat, cmat = xbc[..., :di], xbc[..., di:di + n], xbc[..., di + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    da = dt * a[None, None, :]
    bsz, s = x.shape[:2]
    xh = xs.reshape(bsz, s, h, pp)
    bm = jnp.broadcast_to(bmat[:, :, None, :], (bsz, s, h, n))
    cm = jnp.broadcast_to(cmat[:, :, None, :], (bsz, s, h, n))
    state0 = jnp.zeros((bsz, h, n, pp), jnp.float32)
    y, _ = ssd_chunk_scan(xh, dt, bm, cm, da, cfg.ssm_chunk, state0)
    y = y + p["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    return _mamba_post(p, cfg, y, z, x.dtype)


def mamba_decode(p, cfg, x, cache: MambaCache):
    """x (B,1,d) single step."""
    di, h, pp, n = mamba_dims(cfg)
    z, xbc, dt_raw = _mamba_proj(p, cfg, x)
    xbc1, new_conv = causal_conv_step(cache.conv, xbc[:, 0],
                                      p["conv_w"].astype(x.dtype),
                                      p["conv_b"].astype(x.dtype))
    xbc1 = jax.nn.silu(xbc1)
    xs, bmat, cmat = xbc1[..., :di], xbc1[..., di:di + n], xbc1[..., di + n:]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    da = dt * a[None, :]
    bsz = x.shape[0]
    xh = xs.reshape(bsz, h, pp)
    bm = jnp.broadcast_to(bmat[:, None, :], (bsz, h, n))
    cm = jnp.broadcast_to(cmat[:, None, :], (bsz, h, n))
    y, s_new = ssd_decode_step(cache.state, xh, dt, bm, cm, da)
    y = y + p["d_skip"][None, :, None] * xh.astype(jnp.float32)
    out = _mamba_post(p, cfg, y[:, None], z, x.dtype)
    return out, MambaCache(s_new, new_conv)


def init_mamba_cache(cfg, batch: int, dtype) -> MambaCache:
    di, h, pp, n = mamba_dims(cfg)
    return MambaCache(jnp.zeros((batch, h, n, pp), jnp.float32),
                      jnp.zeros((batch, CONV_W - 1, di + 2 * n), dtype))


# --------------------------------------------------------------------------- #
# mLSTM block (xlstm) — linear attention with exp input / sigmoid forget gate
# --------------------------------------------------------------------------- #
class MLSTMCache(NamedTuple):
    state: jax.Array       # (B, H, DK, DV+1) — last column is the normalizer
    conv: jax.Array        # (B, CONV_W-1, di)


def mlstm_dims(cfg):
    di = cfg.ssm_expand * cfg.d_model
    h = cfg.num_heads
    dk = di // h
    return di, h, dk


def mlstm_schema(cfg) -> dict:
    d = cfg.d_model
    di, h, dk = mlstm_dims(cfg)
    return {
        "w_up": PSpec((d, 2 * di), ("embed", "ssm_inner")),
        "conv_w": PSpec((CONV_W, di), (None, None), "normal", 0.2),
        "conv_b": PSpec((di,), (None,), "zeros"),
        "wq": PSpec((di, di), ("ssm_inner", None)),
        "wk": PSpec((di, di), ("ssm_inner", None)),
        "wv": PSpec((di, di), ("ssm_inner", None)),
        "w_igate": PSpec((di, h), (None, None), "normal", 0.05),
        "b_igate": PSpec((h,), (None,), "zeros"),
        "w_fgate": PSpec((di, h), (None, None), "normal", 0.05),
        "b_fgate": PSpec((h,), (None,), "ones"),
        "norm": {"scale": PSpec((di,), ("ssm_inner",), "ones")},
        "w_down": PSpec((di, d), ("ssm_inner", "embed")),
    }


def _mlstm_qkvif(p, cfg, x):
    di, h, dk = mlstm_dims(cfg)
    up = x @ p["w_up"].astype(x.dtype)
    xm, z = up[..., :di], up[..., di:]
    xc = jax.nn.silu(causal_conv(xm, p["conv_w"].astype(x.dtype),
                                 p["conv_b"].astype(x.dtype)))
    shp = x.shape[:-1] + (h, dk)
    q = (xc @ p["wq"].astype(x.dtype)).reshape(shp) / (dk ** 0.5)
    k = (xc @ p["wk"].astype(x.dtype)).reshape(shp)
    v = (xm @ p["wv"].astype(x.dtype)).reshape(shp)
    ig = xc @ p["w_igate"].astype(x.dtype) + p["b_igate"].astype(x.dtype)
    fg = xc @ p["w_fgate"].astype(x.dtype) + p["b_fgate"].astype(x.dtype)
    # exponential input gate (clamped for stability), sigmoid forget gate
    i_gate = jnp.exp(jnp.clip(ig.astype(jnp.float32), -8.0, 8.0))
    log_f = jax.nn.log_sigmoid(fg.astype(jnp.float32))
    return q, k, v, i_gate, log_f, z, xm


def _mlstm_read(y_aug, z, p, cfg, x_dtype):
    di, h, dk = mlstm_dims(cfg)
    num, den = y_aug[..., :-1], y_aug[..., -1:]
    y = num / jnp.maximum(jnp.abs(den), 1.0)
    y = y.reshape(y.shape[:-2] + (di,)).astype(x_dtype)
    y = apply_norm(p["norm"], y) * jax.nn.silu(z)
    return y @ p["w_down"].astype(x_dtype)


def mlstm_forward(p, cfg, x):
    di, h, dk = mlstm_dims(cfg)
    bsz, s = x.shape[:2]
    q, k, v, ig, log_f, z, _ = _mlstm_qkvif(p, cfg, x)
    # augment v with a ones channel → the normalizer recurrence rides along
    v_aug = jnp.concatenate(
        [v.astype(jnp.float32), jnp.ones(v.shape[:-1] + (1,), jnp.float32)], -1)
    state0 = jnp.zeros((bsz, h, dk, dk + 1), jnp.float32)
    y_aug, _ = ssd_chunk_scan(v_aug, ig, k, q, log_f, cfg.ssm_chunk, state0)
    return _mlstm_read(y_aug, z, p, cfg, x.dtype)


def mlstm_decode(p, cfg, x, cache: MLSTMCache):
    di, h, dk = mlstm_dims(cfg)
    bsz = x.shape[0]
    up = x @ p["w_up"].astype(x.dtype)
    xm, z = up[..., :di], up[..., di:]
    xc1, new_conv = causal_conv_step(cache.conv, xm[:, 0],
                                     p["conv_w"].astype(x.dtype),
                                     p["conv_b"].astype(x.dtype))
    xc1 = jax.nn.silu(xc1)
    q = (xc1 @ p["wq"].astype(x.dtype)).reshape(bsz, h, dk) / (dk ** 0.5)
    k = (xc1 @ p["wk"].astype(x.dtype)).reshape(bsz, h, dk)
    v = (xm[:, 0] @ p["wv"].astype(x.dtype)).reshape(bsz, h, dk)
    ig = jnp.exp(jnp.clip((xc1 @ p["w_igate"].astype(x.dtype) +
                           p["b_igate"].astype(x.dtype)).astype(jnp.float32), -8, 8))
    log_f = jax.nn.log_sigmoid((xc1 @ p["w_fgate"].astype(x.dtype) +
                                p["b_fgate"].astype(x.dtype)).astype(jnp.float32))
    v_aug = jnp.concatenate([v.astype(jnp.float32),
                             jnp.ones((bsz, h, 1), jnp.float32)], -1)
    y_aug, s_new = ssd_decode_step(cache.state, v_aug, ig, k, q, log_f)
    out = _mlstm_read(y_aug[:, None], z, p, cfg, x.dtype)
    return out, MLSTMCache(s_new, new_conv)


def init_mlstm_cache(cfg, batch: int, dtype) -> MLSTMCache:
    di, h, dk = mlstm_dims(cfg)
    return MLSTMCache(jnp.zeros((batch, h, dk, dk + 1), jnp.float32),
                      jnp.zeros((batch, CONV_W - 1, di), dtype))


# --------------------------------------------------------------------------- #
# sLSTM block (xlstm) — recurrent scalar LSTM with exponential gating
# --------------------------------------------------------------------------- #
class SLSTMCache(NamedTuple):
    c: jax.Array   # (B, H, dh)
    n: jax.Array
    m: jax.Array
    h: jax.Array


def slstm_dims(cfg):
    h = cfg.num_heads
    dh = cfg.d_model // h
    return h, dh


def slstm_schema(cfg) -> dict:
    d = cfg.d_model
    h, dh = slstm_dims(cfg)
    ffd = max(8, int(d * 4 // 3))
    return {
        "w_x": PSpec((d, 4 * d), ("embed", None)),
        "r_h": PSpec((h, dh, 4 * dh), (None, None, None), "normal", 0.05),
        "b": PSpec((4 * d,), (None,), "zeros"),
        "norm": {"scale": PSpec((d,), ("embed",), "ones")},
        "w_ff1": PSpec((d, ffd), ("embed", "ff")),
        "w_ff2": PSpec((ffd, d), ("ff", "embed")),
    }


def _slstm_cell(carry: SLSTMCache, gx, r_h):
    """gx: (B, H, dh, 4) pre-activations from x; recurrent part added here."""
    c, n, m, hprev = carry
    rec = jnp.einsum("bhd,hdk->bhk", hprev, r_h).reshape(gx.shape)
    g = (gx + rec).astype(jnp.float32)
    gi, gf, gz, go = g[..., 0], g[..., 1], g[..., 2], g[..., 3]
    m_new = jnp.maximum(gf + m, gi)
    i = jnp.exp(gi - m_new)
    f = jnp.exp(gf + m - m_new)
    c_new = f * c + i * jnp.tanh(gz)
    n_new = f * n + i
    h_new = jax.nn.sigmoid(go) * c_new / jnp.maximum(n_new, 1.0)
    return SLSTMCache(c_new, n_new, m_new, h_new), h_new


def slstm_forward(p, cfg, x):
    h, dh = slstm_dims(cfg)
    bsz, s, d = x.shape
    gx = (x @ p["w_x"].astype(x.dtype)).reshape(bsz, s, h, dh, 4)
    carry = SLSTMCache(*[jnp.zeros((bsz, h, dh), jnp.float32) for _ in range(3)],
                       jnp.zeros((bsz, h, dh), jnp.float32))
    r_h = p["r_h"].astype(jnp.float32)

    def step(c, g):
        return _slstm_cell(c, g + p["b"].astype(jnp.float32).reshape(h, dh, 4),
                           r_h)

    _, hs = jax.lax.scan(step, carry, gx.swapaxes(0, 1).astype(jnp.float32))
    y = hs.swapaxes(0, 1).reshape(bsz, s, d).astype(x.dtype)
    y = apply_norm(p["norm"], y)
    return jax.nn.gelu(y @ p["w_ff1"].astype(x.dtype)) @ p["w_ff2"].astype(x.dtype)


def slstm_decode(p, cfg, x, cache: SLSTMCache):
    h, dh = slstm_dims(cfg)
    bsz, _, d = x.shape
    gx = (x[:, 0] @ p["w_x"].astype(x.dtype)).reshape(bsz, h, dh, 4)
    new_cache, h_new = _slstm_cell(
        cache, gx.astype(jnp.float32) +
        p["b"].astype(jnp.float32).reshape(h, dh, 4),
        p["r_h"].astype(jnp.float32))
    y = h_new.reshape(bsz, 1, d).astype(x.dtype)
    y = apply_norm(p["norm"], y)
    out = jax.nn.gelu(y @ p["w_ff1"].astype(x.dtype)) @ p["w_ff2"].astype(x.dtype)
    return out, new_cache


def init_slstm_cache(cfg, batch: int, dtype) -> SLSTMCache:
    h, dh = slstm_dims(cfg)
    z = jnp.zeros((batch, h, dh), jnp.float32)
    return SLSTMCache(z, z, z, z)
