"""Attention blocks: GQA (RoPE/M-RoPE) and MLA (deepseek-v3), train + decode.

Sharding story (DESIGN §7):
  * training/prefill — q heads sharded over `model` (padded to a multiple of
    the mesh size at schema-build time); kv heads replicated when
    kv < mesh_model (their activations are small), sharded otherwise.
  * decode — the KV cache is sharded over `model` on the SEQUENCE axis;
    softmax over the sharded axis makes GSPMD emit the flash-decode
    max/sum/output all-reduces automatically.  No head-divisibility
    constraint, no cache padding.
  * MLA decode uses the absorbed form (score against the 512-d latent cache
    directly) — the compact-cache property that makes MLA serve 32k+.

The XLA attention path is chunked over query blocks (O(S·block) memory); the
Pallas flash kernel (repro.kernels.flash_attention) is the TPU hot path for
training and is validated against the same math.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .schema import PSpec
from .layers import apply_rope, apply_norm, norm_schema

NEG_INF = -1e30


# --------------------------------------------------------------------------- #
# schemas
# --------------------------------------------------------------------------- #
def gqa_schema(cfg, mesh_model: int) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hp = cfg.padded_heads(mesh_model)
    kv = cfg.padded_kv_heads(mesh_model)
    sch = {
        "wq": PSpec((d, hp, hd), ("embed", "heads", None)),
        "wk": PSpec((d, kv, hd), ("embed", "kv_heads", None)),
        "wv": PSpec((d, kv, hd), ("embed", "kv_heads", None)),
        "wo": PSpec((hp, hd, d), ("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        sch["bq"] = PSpec((hp, hd), ("heads", None), "zeros")
        sch["bk"] = PSpec((kv, hd), ("kv_heads", None), "zeros")
        sch["bv"] = PSpec((kv, hd), ("kv_heads", None), "zeros")
    return sch


def mla_schema(cfg, mesh_model: int) -> dict:
    d = cfg.d_model
    hp = cfg.padded_heads(mesh_model)
    qk = cfg.mla_qk_nope_dim + cfg.mla_qk_rope_dim
    return {
        "wq_a": PSpec((d, cfg.mla_q_lora_rank), ("embed", None)),
        "q_norm": {"scale": PSpec((cfg.mla_q_lora_rank,), (None,), "ones")},
        "wq_b": PSpec((cfg.mla_q_lora_rank, hp, qk), (None, "heads", None)),
        "wkv_a": PSpec((d, cfg.mla_kv_lora_rank + cfg.mla_qk_rope_dim),
                       ("embed", None)),
        "kv_norm": {"scale": PSpec((cfg.mla_kv_lora_rank,), (None,), "ones")},
        "wkv_b": PSpec((cfg.mla_kv_lora_rank, hp,
                        cfg.mla_qk_nope_dim + cfg.mla_v_dim),
                       (None, "heads", None)),
        "wo": PSpec((hp, cfg.mla_v_dim, d), ("heads", None, "embed")),
    }


def attention_schema(cfg, mesh_model: int) -> dict:
    if cfg.attention_type == "mla":
        return mla_schema(cfg, mesh_model)
    return gqa_schema(cfg, mesh_model)


# --------------------------------------------------------------------------- #
# caches
# --------------------------------------------------------------------------- #
class KVCache(NamedTuple):
    """GQA cache: k/v (B, KV, Smax, hd).  MLA: ckv (B, Smax, latent),
    krope (B, Smax, rope) — stored in k/v respectively (2D per token)."""
    k: jax.Array
    v: jax.Array


def init_gqa_cache(cfg, batch: int, max_len: int, dtype,
                   mesh_model: int = 1) -> KVCache:
    hd = cfg.resolved_head_dim
    shp = (batch, cfg.padded_kv_heads(mesh_model), max_len, hd)
    return KVCache(jnp.zeros(shp, dtype), jnp.zeros(shp, dtype))


def init_mla_cache(cfg, batch: int, max_len: int, dtype) -> KVCache:
    return KVCache(jnp.zeros((batch, max_len, cfg.mla_kv_lora_rank), dtype),
                   jnp.zeros((batch, max_len, cfg.mla_qk_rope_dim), dtype))


# --------------------------------------------------------------------------- #
# chunked causal attention (XLA path)
# --------------------------------------------------------------------------- #
def _causal_attn_chunked(q, k, v, *, chunk: int = 512, causal: bool = True,
                         window: int = 0):
    """q/k (B,H,S,D); v (B,KV,S,Dv) — Dv may differ (MLA).  GQA by head
    grouping; O(S·chunk) memory."""
    b, h, s, d = q.shape
    dv = v.shape[-1]
    kv = k.shape[1]
    group = h // kv
    qg = q.reshape(b, kv, group, s, d)
    scale = 1.0 / (d ** 0.5)
    nchunks = -(-s // chunk)
    pad_s = nchunks * chunk
    if pad_s != s:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, pad_s - s), (0, 0)))
    qc = qg.reshape(b, kv, group, nchunks, chunk, d).transpose(3, 0, 1, 2, 4, 5)
    kpos = jnp.arange(k.shape[2])

    def one_chunk(ci, qch):
        # qch (B,KV,G,C,D)
        sco = jnp.einsum("bkgcd,bksd->bkgcs", qch.astype(jnp.float32),
                         k.astype(jnp.float32)) * scale
        qpos = ci * chunk + jnp.arange(chunk)
        mask = jnp.ones((chunk, k.shape[2]), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        sco = jnp.where(mask[None, None, None], sco, NEG_INF)
        p = jax.nn.softmax(sco, axis=-1)
        return jnp.einsum("bkgcs,bksd->bkgcd", p, v.astype(jnp.float32))

    out = jax.lax.map(lambda args: one_chunk(*args),
                      (jnp.arange(nchunks), qc))              # (N,B,KV,G,C,Dv)
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(b, kv, group, pad_s, dv)
    return out[:, :, :, :s].reshape(b, h, s, dv).astype(q.dtype)


# --------------------------------------------------------------------------- #
# GQA forward
# --------------------------------------------------------------------------- #
def _project_qkv(p, cfg, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    return q, k, v


def gqa_forward(p, cfg, x, positions, *, causal: bool = True,
                window: int = 0) -> jax.Array:
    """Full-sequence attention (training / prefill).  x: (B, S, d)."""
    q, k, v = _project_qkv(p, cfg, x, positions)
    qh = q.transpose(0, 2, 1, 3)                 # (B, Hp, S, hd)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    # pad-head grouping: Hp % KV == 0 is guaranteed only when Hp//KV divides
    # evenly; pad kv virtually by repeating the last kv head for extra groups.
    hp = qh.shape[1]
    kvh = kh.shape[1]
    if hp % kvh != 0:
        reps = -(-hp // kvh)
        kh = jnp.repeat(kh, reps, axis=1)[:, :hp]
        vh = jnp.repeat(vh, reps, axis=1)[:, :hp]
    out = _causal_attn_chunked(qh, kh, vh, causal=causal, window=window)
    out = out.transpose(0, 2, 1, 3)              # (B, S, Hp, hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def gqa_prefill(p, cfg, x, positions, cache: KVCache, *, window: int = 0):
    """Prefill: forward + write k/v into the cache at [0, S)."""
    q, k, v = _project_qkv(p, cfg, x, positions)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    new_cache = KVCache(
        jax.lax.dynamic_update_slice(cache.k, kh.astype(cache.k.dtype), (0, 0, 0, 0)),
        jax.lax.dynamic_update_slice(cache.v, vh.astype(cache.v.dtype), (0, 0, 0, 0)))
    qh = q.transpose(0, 2, 1, 3)
    hp, kvh = qh.shape[1], kh.shape[1]
    if hp % kvh != 0:
        reps = -(-hp // kvh)
        kh = jnp.repeat(kh, reps, axis=1)[:, :hp]
        vh = jnp.repeat(vh, reps, axis=1)[:, :hp]
    out = _causal_attn_chunked(qh, kh, vh, causal=True, window=window)
    out = out.transpose(0, 2, 1, 3)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype)), new_cache


def gqa_decode(p, cfg, x, positions, cache: KVCache, cur_len, *,
               window: int = 0):
    """One-token decode.  x: (B, 1, d); cache k/v (B, KV, Smax, hd).

    The cache sequence axis may be sharded over `model`; the softmax over it
    then lowers to the flash-decode all-reduce pattern under GSPMD.
    """
    b = x.shape[0]
    q, k, v = _project_qkv(p, cfg, x, positions)
    # append new kv at cur_len
    knew = k.transpose(0, 2, 1, 3).astype(cache.k.dtype)   # (B, KV, 1, hd)
    vnew = v.transpose(0, 2, 1, 3).astype(cache.v.dtype)
    smax = cache.k.shape[2]
    zero = jnp.zeros((), jnp.int32)
    ck = jax.lax.dynamic_update_slice(cache.k, knew, (zero, zero, cur_len, zero))
    cv = jax.lax.dynamic_update_slice(cache.v, vnew, (zero, zero, cur_len, zero))
    new_cache = KVCache(ck, cv)

    qh = q.transpose(0, 2, 1, 3)                            # (B, Hp, 1, hd)
    hp, kvh = qh.shape[1], ck.shape[1]
    group = -(-hp // kvh)
    qg = qh.reshape(b, kvh, -1, 1, qh.shape[-1]) if hp % kvh == 0 else None
    if qg is None:
        kk = jnp.repeat(ck, group, axis=1)[:, :hp]
        vv = jnp.repeat(cv, group, axis=1)[:, :hp]
        sco = jnp.einsum("bhqd,bhsd->bhqs", qh.astype(jnp.float32),
                         kk.astype(jnp.float32))
    else:
        kk, vv = ck, cv
        sco = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(jnp.float32),
                         kk.astype(jnp.float32)).reshape(b, hp, 1, smax)
    sco = sco / (qh.shape[-1] ** 0.5)
    pos_mask = jnp.arange(smax) <= cur_len
    if window:
        pos_mask &= jnp.arange(smax) > cur_len - window
    sco = jnp.where(pos_mask[None, None, None], sco, NEG_INF)
    prob = jax.nn.softmax(sco, axis=-1)
    if qg is None:
        out = jnp.einsum("bhqs,bhsd->bhqd", prob, vv.astype(jnp.float32))
    else:
        out = jnp.einsum("bkgqs,bksd->bkgqd",
                         prob.reshape(b, kvh, group, 1, smax),
                         vv.astype(jnp.float32)).reshape(b, hp, 1, -1)
    out = out.astype(x.dtype).transpose(0, 2, 1, 3)         # (B, 1, Hp, hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype)), new_cache


# --------------------------------------------------------------------------- #
# MLA forward (deepseek-v3)
# --------------------------------------------------------------------------- #
def _mla_qkv(p, cfg, x, positions):
    nope, rope = cfg.mla_qk_nope_dim, cfg.mla_qk_rope_dim
    cq = apply_norm(p["q_norm"], x @ p["wq_a"].astype(x.dtype))
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"].astype(x.dtype))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv_full = x @ p["wkv_a"].astype(x.dtype)
    ckv, k_rope = ckv_full[..., : cfg.mla_kv_lora_rank], ckv_full[..., cfg.mla_kv_lora_rank:]
    ckv = apply_norm(p["kv_norm"], ckv)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return q_nope, q_rope, ckv, k_rope


def mla_forward(p, cfg, x, positions, *, causal: bool = True) -> jax.Array:
    """Training/prefill MLA: expand latent to full k/v (FLOP-optimal for S≫1)."""
    nope = cfg.mla_qk_nope_dim
    q_nope, q_rope, ckv, k_rope = _mla_qkv(p, cfg, x, positions)
    kv = jnp.einsum("bsr,rhk->bshk", ckv, p["wkv_b"].astype(x.dtype))
    k_nope, v = kv[..., :nope], kv[..., nope:]
    hp = q_nope.shape[2]
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :],
                                k_rope.shape[:2] + (hp, k_rope.shape[-1]))
    q = jnp.concatenate([q_nope, q_rope], -1).transpose(0, 2, 1, 3)
    k = jnp.concatenate([k_nope, k_rope_b], -1).transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    out = _causal_attn_chunked(q, k, vh, causal=causal)
    out = out.transpose(0, 2, 1, 3)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def mla_prefill(p, cfg, x, positions, cache: KVCache):
    q_nope, q_rope, ckv, k_rope = _mla_qkv(p, cfg, x, positions)
    new_cache = KVCache(
        jax.lax.dynamic_update_slice(cache.k, ckv.astype(cache.k.dtype), (0, 0, 0)),
        jax.lax.dynamic_update_slice(cache.v, k_rope.astype(cache.v.dtype), (0, 0, 0)))
    out = mla_forward(p, cfg, x, positions, causal=True)
    return out, new_cache


def mla_decode(p, cfg, x, positions, cache: KVCache, cur_len):
    """Absorbed-form decode against the latent cache (B, Smax, 512 + 64)."""
    nope = cfg.mla_qk_nope_dim
    q_nope, q_rope, ckv_new, k_rope_new = _mla_qkv(p, cfg, x, positions)
    smax = cache.k.shape[1]
    zero = jnp.zeros((), jnp.int32)
    ck = jax.lax.dynamic_update_slice(
        cache.k, ckv_new.astype(cache.k.dtype), (zero, cur_len, zero))
    cr = jax.lax.dynamic_update_slice(
        cache.v, k_rope_new.astype(cache.v.dtype), (zero, cur_len, zero))
    new_cache = KVCache(ck, cr)

    w_uk = p["wkv_b"][..., :nope]                       # (latent, H, nope)
    w_uv = p["wkv_b"][..., nope:]                       # (latent, H, v)
    # absorb: q_eff (B,1,H,latent)
    q_eff = jnp.einsum("bshk,rhk->bshr", q_nope, w_uk.astype(x.dtype))
    sco = (jnp.einsum("bshr,bSr->bshS", q_eff.astype(jnp.float32),
                      ck.astype(jnp.float32)) +
           jnp.einsum("bshk,bSk->bshS", q_rope.astype(jnp.float32),
                      cr.astype(jnp.float32)))
    sco = sco / ((nope + cfg.mla_qk_rope_dim) ** 0.5)
    mask = jnp.arange(smax) <= cur_len
    sco = jnp.where(mask[None, None, None], sco, NEG_INF)
    prob = jax.nn.softmax(sco, axis=-1)
    ctx = jnp.einsum("bshS,bSr->bshr", prob, ck.astype(jnp.float32))
    out = jnp.einsum("bshr,rhk->bshk", ctx.astype(x.dtype), w_uv.astype(x.dtype))
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype)), new_cache


# --------------------------------------------------------------------------- #
# cross attention (whisper decoder)
# --------------------------------------------------------------------------- #
def cross_schema(cfg, mesh_model: int) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hp = cfg.padded_heads(mesh_model)
    return {
        "wq": PSpec((d, hp, hd), ("embed", "heads", None)),
        "wk": PSpec((d, hp, hd), ("embed", "heads", None)),
        "wv": PSpec((d, hp, hd), ("embed", "heads", None)),
        "wo": PSpec((hp, hd, d), ("heads", None, "embed")),
    }


def cross_forward(p, cfg, x, enc_out) -> jax.Array:
    """Decoder cross-attention over encoder output (no cache needed: enc kv
    computed on the fly — enc seq is short)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype)).transpose(0, 2, 1, 3)
    k = jnp.einsum("bsd,dhk->bshk", enc_out.astype(x.dtype),
                   p["wk"].astype(x.dtype)).transpose(0, 2, 1, 3)
    v = jnp.einsum("bsd,dhk->bshk", enc_out.astype(x.dtype),
                   p["wv"].astype(x.dtype)).transpose(0, 2, 1, 3)
    sco = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                     k.astype(jnp.float32)) / (q.shape[-1] ** 0.5)
    prob = jax.nn.softmax(sco, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", prob, v.astype(jnp.float32))
    out = out.astype(x.dtype).transpose(0, 2, 1, 3)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
