"""Logical-axis → mesh-axis rules (DESIGN §7).

Single-pod mesh: (data=16, model=16).  Multi-pod: (pod=2, data=16, model=16)
— `pod` extends data parallelism; with FSDP the weights/optimizer shard over
("data","pod") as well (ZeRO-3).

Per-config adjustments:
  * kv_heads shard over `model` only when divisible (else replicated — their
    activations are small; the decode cache shards over the sequence axis
    instead, see attention.py).
  * FSDP configs shard the `embed` (d_model) dimension of weights over
    `data`(+`pod`), all-gathered by XLA at use — ZeRO-3 semantics for free.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from .schema import logical_axes


def _ambient_mesh():
    try:
        m = jax.interpreters.pxla.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def constrain_batch(x, *, sharded_tail: dict[int, str] | None = None,
                    batch_over_model: bool = False):
    """Pin activation sharding: batch over data(+pod), rest replicated.

    Without this, GSPMD can propagate the FSDP *weight* sharding into the
    remat-saved activation stacks — replicating batch and sharding d_model
    over `data` instead (measured: 16× activation traffic on the dense train
    cells; see EXPERIMENTS.md §Perf iteration 1).  No-op outside a mesh.

    ``sharded_tail``: optional {dim: axis} for extra dims (e.g. vocab logits
    {2: "model"}).
    """
    import os
    if os.environ.get("REPRO_NO_ACT_CONSTRAINT"):  # hillclimb A/B switch
        return x
    m = _ambient_mesh()
    if m is None:
        return x
    names = m.axis_names
    batch_names = ("pod", "data", "model") if batch_over_model else ("pod", "data")
    data_axes = tuple(a for a in batch_names if a in names)
    if not data_axes:
        return x
    batch_dim = len(data_axes) == 1 and data_axes[0] or data_axes
    spec = [None] * x.ndim
    spec[0] = batch_dim
    for d, ax in (sharded_tail or {}).items():
        if ax in names:
            spec[d] = ax
    return jax.lax.with_sharding_constraint(x, P(*spec))


def make_rules(cfg, *, mesh_model: int, multi_pod: bool, fsdp: bool | None = None):
    fsdp = cfg.fsdp if fsdp is None else fsdp
    data_axes = ("pod", "data") if multi_pod else ("data",)
    if not getattr(cfg, "tensor_parallel", True):
        # sub-1B archs: replicate weights, DP over (data × model)
        return {None: None, "layers": None, "vocab": None, "heads": None,
                "ff": None, "moe_ff": None, "expert": None, "ssm_inner": None,
                "embed": data_axes if fsdp else None, "kv_heads": None}
    rules: dict[str | None, object] = {
        None: None,
        "layers": None,
        "vocab": "model",
        "heads": "model",
        "ff": "model",
        "moe_ff": None,            # expert dim already uses `model` (EP)
        "expert": "model",
        "ssm_inner": "model",
        "embed": data_axes if fsdp else None,   # ZeRO-3 weight shard
        "kv_heads": "model" if cfg.num_kv_heads % mesh_model == 0 else None,
    }
    return rules


def specs_from_schema(schema, rules) -> object:
    """PSpec tree → PartitionSpec tree."""
    axes = logical_axes(schema)

    def to_pspec(ax):
        return P(*[rules.get(a, None) for a in ax])

    return jax.tree_util.tree_map(to_pspec, axes,
                                  is_leaf=lambda x: isinstance(x, tuple) and
                                  all(isinstance(e, (str, type(None))) for e in x))


def batch_specs(cfg, shape_kind: str, multi_pod: bool):
    """Input shardings for a (tokens, ...) batch."""
    data = ("pod", "data") if multi_pod else "data"
    specs = {"tokens": P(data, None), "positions": P(None, data, None)
             if cfg.mrope_sections else P(data, None)}
    if cfg.frontend == "vision_stub":
        specs["patch_embeds"] = P(data, None, None)
    if cfg.frontend == "audio_stub":
        specs["frame_embeds"] = P(data, None, None)
    if shape_kind == "train":
        specs["labels"] = P(data, None)
    return specs


def constrain_spec(x, spec: P):
    """with_sharding_constraint against the ambient mesh (no-op outside)."""
    import os
    if os.environ.get("REPRO_NO_MOE_CONSTRAINT"):
        return x
    m = _ambient_mesh()
    if m is None:
        return x
    names = set(m.axis_names)

    def keep(ax):
        if ax is None:
            return None
        if isinstance(ax, tuple):
            kept = tuple(a for a in ax if a in names)
            return kept if kept else None
        return ax if ax in names else None

    return jax.lax.with_sharding_constraint(x, P(*[keep(a) for a in spec]))


def cache_spec_tree(cfg, mesh_model: int, multi_pod: bool):
    """Decode-cache shardings mirroring ``transformer.init_cache``:
    batch over data(+pod); the attention cache SEQUENCE axis over `model`
    (flash-decode, no head-divisibility constraint); SSM states over heads /
    channels where divisible, replicated otherwise (they are small).
    """
    from repro.models import transformer as tmod
    from repro.models import attention as attn_mod
    from repro.models import ssm as ssm_mod

    data = ("pod", "data") if multi_pod else "data"

    def div(sz):  # shard over model only when the dim divides evenly
        return "model" if sz % mesh_model == 0 else None

    def kind_spec(kind):
        if kind in ("attn", "moe"):
            if cfg.attention_type == "mla":
                return attn_mod.KVCache(P(None, data, "model", None),
                                        P(None, data, "model", None))
            return attn_mod.KVCache(P(None, data, None, "model", None),
                                    P(None, data, None, "model", None))
        if kind == "mamba":
            di, h, p_, n = ssm_mod.mamba_dims(cfg)
            return ssm_mod.MambaCache(P(None, data, div(h), None, None),
                                      P(None, data, None, div(di + 2 * n)))
        if kind == "mlstm":
            di, h, dk = ssm_mod.mlstm_dims(cfg)
            return ssm_mod.MLSTMCache(P(None, data, div(h), None, None),
                                      P(None, data, None, div(di)))
        if kind == "slstm":
            h, dh = ssm_mod.slstm_dims(cfg)
            s = P(None, data, div(h), None)
            return ssm_mod.SLSTMCache(s, s, s, s)
        raise ValueError(kind)

    tree: dict = {}
    for si, seg in enumerate(tmod.segment_plan(cfg)):
        tree[f"seg{si}"] = {f"pos{j}": kind_spec(k)
                            for j, k in enumerate(seg.kinds)}
    if cfg.attn_every:
        tree["shared_attn"] = attn_mod.KVCache(
            P(None, data, None, "model", None),
            P(None, data, None, "model", None))
    return tree
