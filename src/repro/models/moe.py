"""Mixture-of-Experts with sort-based dispatch and predicted capacity.

Dispatch is sort-based (megablocks-style, TPU-static): assignments are sorted
by expert id, each token-slot gets a position-within-expert, and slots beyond
the expert's static ``capacity`` are dropped.  Cost is O(T·k log T·k) for the
sort plus O(T·k·d) gathers — no O(T·E·C) one-hot dispatch tensor.

Capacity is where the paper lands in the LM stack (DESIGN §4): the static
per-expert capacity is the predicted output structure of the token→expert
dispatch.  ``repro.core.moe_capacity.predict_dispatch_capacity`` supplies it
from a sampled calibration batch (sampled-CR, eq. 4); the fallback is the
classic worst-case ``capacity_factor·T·k/E``.

Experts are sharded over `model` (EP); the scatter into the (E, C, d) buffer
reshards tokens from `data` to `model` — GSPMD emits the all-to-all pair that
a hand-written EP exchange would.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .schema import PSpec
from .layers import mlp_schema, apply_mlp


class MoEAux(NamedTuple):
    load_balance_loss: jax.Array
    router_z_loss: jax.Array
    dropped_fraction: jax.Array
    expert_load: jax.Array         # (E,) fraction of assignments per expert


def moe_schema(cfg) -> dict:
    d, e = cfg.d_model, cfg.moe_num_experts
    ff = cfg.moe_d_ff or cfg.d_ff
    sch = {
        "router": PSpec((d, e), ("embed", "expert")),
        "wi": PSpec((e, d, ff), ("expert", "embed", "moe_ff")),
        "wg": PSpec((e, d, ff), ("expert", "embed", "moe_ff")),
        "wo": PSpec((e, ff, d), ("expert", "moe_ff", "embed")),
    }
    if cfg.moe_shared_experts:
        sch["shared"] = mlp_schema(cfg, d_ff=ff * cfg.moe_shared_experts)
    return sch


def default_capacity(cfg, tokens_per_group: int) -> int:
    """Worst-case (upper-bound-method analogue) per-group capacity."""
    e, k = cfg.moe_num_experts, cfg.moe_top_k
    cap = int(tokens_per_group * k / e * cfg.moe_capacity_factor)
    return max(4, -(-cap // 4) * 4)


def _dispatch_one_group(xg, gates, ids, e: int, k: int, capacity: int):
    """Sort-based dispatch for ONE group.  xg (S,d); gates/ids (S,k)."""
    s, d = xg.shape
    flat_e = ids.reshape(s * k)
    flat_t = jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)
    flat_g = gates.reshape(s * k)
    order = jnp.argsort(flat_e)                                       # stable
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    counts = jnp.zeros(e, jnp.int32).at[se].add(1)
    start = jnp.cumsum(counts) - counts
    pos = jnp.arange(s * k, dtype=jnp.int32) - start[se]
    keep = pos < capacity
    dest = jnp.where(keep, se * capacity + pos, e * capacity)         # drop slot
    buf = jnp.zeros((e * capacity, d), xg.dtype).at[dest].add(
        xg[st], mode="drop").reshape(e, capacity, d)
    return buf, (keep, dest, st, sg, counts)


def _combine_one_group(out, dispatch_info, s: int, e: int, capacity: int,
                       dtype):
    keep, dest, st, sg, _ = dispatch_info
    out_flat = out.reshape(e * capacity, -1)
    contrib = jnp.where(keep[:, None],
                        out_flat[jnp.minimum(dest, e * capacity - 1)], 0.0)
    return jnp.zeros((s, out_flat.shape[-1]), dtype).at[st].add(
        contrib * sg[:, None].astype(dtype))


def apply_moe(p, cfg, x, *, capacity: int):
    """x: (B, S, d) → (y, MoEAux).

    Grouped dispatch: one group per batch row, so the dispatch sort and
    position bookkeeping stay LOCAL to the `data` shard (S·k-element sorts),
    and the (G, E, C, d) buffer shards G over `data` and E over `model` —
    the data↔model reshard between the scatter and the expert einsum is the
    EP all-to-all pair.  ``capacity`` is per group and static; the paper's
    predictor supplies it (DESIGN §4), worst-case ``default_capacity`` is the
    fallback.
    """
    b, s, d = x.shape
    e, k = cfg.moe_num_experts, cfg.moe_top_k

    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)    # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, k)                              # (B,S,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    buf, info = jax.vmap(
        lambda xg, gg, ii: _dispatch_one_group(xg, gg, ii, e, k, capacity)
    )(x, gates, ids)                                                  # (B,E,C,d)

    # ---- expert MLPs (E sharded over `model`) ----
    # pin the intended EP layout explicitly: (G@data, E@model, C, d); the
    # data→model reshard between scatter and einsum is the EP all-to-all.
    # Training-scale only: for decode (capacity ≤ a few slots) the buffers
    # are tiny and pinning forces per-step resharding (measured 14× worse
    # on deepseek decode_32k — EXPERIMENTS §Perf iteration 5).
    from .sharding import constrain_spec
    from jax.sharding import PartitionSpec as P
    pin = capacity >= 16
    if pin:
        buf = constrain_spec(buf, P(("pod", "data"), "model", None, None))
    h = jnp.einsum("gecd,edf->gecf", buf, p["wi"].astype(x.dtype))
    g = jnp.einsum("gecd,edf->gecf", buf, p["wg"].astype(x.dtype))
    h = jax.nn.silu(g) * h
    if pin:
        h = constrain_spec(h, P(("pod", "data"), "model", None, None))
    out = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(x.dtype))
    if pin:
        out = constrain_spec(out, P(("pod", "data"), "model", None, None))

    y = jax.vmap(
        lambda o, inf: _combine_one_group(o, inf, s, e, capacity, x.dtype)
    )(out, info)                                                      # (B,S,d)

    if "shared" in p:
        y = y + apply_mlp(p["shared"], x)

    # ---- aux losses (Switch-style) ----
    counts = info[4]                                                  # (B,E)
    frac_assign = counts.sum(0).astype(jnp.float32) / (b * s * k)
    mean_prob = probs.mean(axis=(0, 1))
    lb = e * jnp.sum(frac_assign * mean_prob)
    zl = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    keep = info[0]
    dropped = 1.0 - keep.astype(jnp.float32).mean()
    return y, MoEAux(lb, zl, dropped, frac_assign)
