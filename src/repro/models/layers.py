"""Shared model layers (functional JAX; params are plain dict pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .schema import PSpec


# --------------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------------- #
def norm_schema(cfg) -> dict:
    if cfg.norm == "layernorm":
        return {"scale": PSpec((cfg.d_model,), ("embed",), "ones"),
                "bias": PSpec((cfg.d_model,), ("embed",), "zeros")}
    return {"scale": PSpec((cfg.d_model,), ("embed",), "ones")}


def apply_norm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        return (y * p["scale"] + p["bias"]).astype(x.dtype)
    ms = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * p["scale"]).astype(x.dtype)


# --------------------------------------------------------------------------- #
# RoPE (standard + M-RoPE)
# --------------------------------------------------------------------------- #
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               mrope_sections: tuple[int, ...] = ()) -> jax.Array:
    """x: (B, S, H, D).  positions: (B, S) or (3, B, S) for M-RoPE.

    M-RoPE (qwen2-vl): the D/2 rotary frequencies are split into
    ``mrope_sections`` (t, h, w); each section uses its own position stream.
    Text tokens carry identical (t, h, w) positions, so M-RoPE degenerates to
    standard RoPE for them.
    """
    b, s, h, d = x.shape
    inv = rope_freqs(d, theta)  # (d/2,)
    if mrope_sections and positions.ndim == 3:
        assert sum(mrope_sections) == d // 2, (mrope_sections, d)
        pos_parts = []
        for i, sec in enumerate(mrope_sections):
            pos_parts.append(jnp.broadcast_to(positions[i][:, :, None], (b, s, sec)))
        pos = jnp.concatenate(pos_parts, axis=-1)          # (B, S, d/2)
        ang = pos.astype(jnp.float32) * inv[None, None, :]
    else:
        if positions.ndim == 3:
            positions = positions[0]
        ang = positions[:, :, None].astype(jnp.float32) * inv[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]                      # (B, S, 1, d/2)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# MLP
# --------------------------------------------------------------------------- #
def mlp_schema(cfg, d_ff: int | None = None) -> dict:
    ff = d_ff or cfg.d_ff
    d = cfg.d_model
    if cfg.act == "swiglu":
        return {"wi": PSpec((d, ff), ("embed", "ff")),
                "wg": PSpec((d, ff), ("embed", "ff")),
                "wo": PSpec((ff, d), ("ff", "embed"))}
    return {"wi": PSpec((d, ff), ("embed", "ff")),
            "wo": PSpec((ff, d), ("ff", "embed"))}


def apply_mlp(p: dict, x: jax.Array) -> jax.Array:
    h = x @ p["wi"]
    if "wg" in p:  # swiglu
        h = jax.nn.silu(x @ p["wg"]) * h
    else:
        h = jax.nn.gelu(h)
    return h @ p["wo"]


# --------------------------------------------------------------------------- #
# embeddings / head
# --------------------------------------------------------------------------- #
def embed_schema(cfg, padded_vocab: int) -> dict:
    sch = {"tok": PSpec((padded_vocab, cfg.d_model), ("vocab", "embed"), "embed")}
    if not cfg.tie_embeddings:
        sch["head"] = PSpec((cfg.d_model, padded_vocab), ("embed", "vocab"))
    return sch


def embed_tokens(p: dict, tokens: jax.Array, dtype) -> jax.Array:
    return p["tok"].astype(dtype)[tokens]


def lm_head(p: dict, x: jax.Array) -> jax.Array:
    w = p.get("head")
    if w is None:
        w = p["tok"].T
    return (x @ w.astype(x.dtype)).astype(jnp.float32)
