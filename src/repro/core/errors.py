"""Typed SpGEMM error taxonomy (DESIGN.md §9).

Every failure mode of the plan/execute pipeline raises a subclass of
:class:`SpgemmError` carrying structured ``context`` (plan key, bucket /
panel / shard ids, observed vs planned capacities) so a caller — or the
serving engine the ROADMAP builds on top of this — can route, log and
degrade on failures without parsing message strings.

``SpgemmError`` subclasses :class:`ValueError` deliberately: every bare
``ValueError`` this taxonomy replaced keeps satisfying existing
``except ValueError`` callers, so typing the errors is purely additive.

Taxonomy::

    SpgemmError                  base; .context dict, JSON-serializable
    ├── OperandValidationError   malformed operand (CSR invariant broken)
    ├── PlanMismatchError        operand/mesh/template doesn't fit the plan
    ├── CapacityExhaustedError   output slots exhausted beyond recovery
    ├── ShardFailureError        an execution unit (shard/panel/bucket) died
    ├── AdmissionRejectedError   serving front end refused/shed the request
    └── DeadlineExceededError    request deadline passed before completion
"""
from __future__ import annotations


class SpgemmError(ValueError):
    """Base class: message plus a structured, JSON-serializable ``context``.

    ``context`` keys are free-form but the pipeline uses a stable
    vocabulary: ``plan_key`` (hash of the plan's static key), ``operand``,
    ``field``, ``row``, ``index``, ``bucket``/``buckets``, ``panel``,
    ``shard``/``shards``, ``unit``, ``observed``, ``planned``.
    """

    def __init__(self, message: str, **context):
        self.context = {k: v for k, v in context.items() if v is not None}
        super().__init__(message)

    def __str__(self) -> str:
        base = super().__str__()
        if not self.context:
            return base
        ctx = ", ".join(f"{k}={v!r}" for k, v in sorted(self.context.items()))
        return f"{base} [{ctx}]"


class OperandValidationError(SpgemmError):
    """An operand violates a CSR invariant (``core.validate.validate_csr``):
    non-monotone/mis-sized ``rpt``, out-of-range or unsorted ``col``,
    non-finite ``val``, or a broken dtype contract.  ``context`` pinpoints
    the field and the first offending row/entry."""


class PlanMismatchError(SpgemmError):
    """An operand, mesh or template does not match the plan it is used
    with: wrong shape/capacity at ``to_device``, a panel-plan operand whose
    structure fingerprint differs from the planned one, a mesh whose axis
    size differs from the planned shard count, or a template misuse."""


class CapacityExhaustedError(SpgemmError):
    """Output capacity was exhausted and could not (or was not allowed to)
    be recovered: the retry ladder ran out of rounds/ceiling with the
    exact-symbolic fallback disabled, or a truncated result reached
    ``reassemble``.  ``context`` names the offending buckets/panels with
    observed need vs planned capacity."""


class ShardFailureError(SpgemmError):
    """One execution unit failed: a shard/panel exhausted its ladder on the
    distributed path (surfaced by name instead of a collective hang), a
    gather buffer was starved below its payload, or a bucket executor
    raised mid-flight.  ``context`` names the unit (``shard``/``panel``/
    ``bucket``) and chains the original failure as ``__cause__``."""


class AdmissionRejectedError(SpgemmError):
    """The serving front end (:mod:`repro.serve.spgemm_service`) refused a
    request instead of letting it hang or starve the fleet: the bounded
    queue was full (load shedding), the request's cost estimate exceeds the
    whole device budget (it can never be scheduled), or a circuit breaker
    is open for its template.  ``context`` carries ``request``, the
    admission decision (``reason``) and the observed vs planned quantity
    (queue depth vs capacity, estimated vs budget bytes)."""


class DeadlineExceededError(SpgemmError):
    """A request's deadline passed before it reached execution (expired
    while queued) or before its result was produced.  ``context`` carries
    ``request``, ``deadline`` and ``waited`` (seconds on the service
    clock)."""
