# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.

# Unified planner/executor surface (DESIGN.md §6).  Kept as a lazy import
# so `from repro.core import oracle` doesn't drag jax tracing machinery in.


def __getattr__(name):
    if name in ("plan_spgemm", "execute", "reassemble", "plan_cache",
                "SpgemmPlan", "PlanCache", "DistSpgemmOut", "PlanTemplate",
                "TemplateRegistry", "template_registry", "RetryPolicy"):
        from . import plan as _plan
        return getattr(_plan, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
