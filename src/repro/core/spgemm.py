"""Numeric SpGEMM on device (JAX), allocated from the paper's prediction.

Flow (the paper's motivating use-case, Section I):
  1. ``flop_per_row``          — upper bound / load-balance info (Algorithm 1)
  2. ``proposed_predict``      — sampled-CR output-structure prediction (eq. 4)
  3. ``AllocationPlan``        — static output capacities from the prediction
  4. ``spgemm``  (this module) — row-wise numeric phase writing into the
                                  predicted-size buffers, overflow-reported.

The numeric accumulation mirrors the symbolic TPU adaptation: expand products
into a static (rows, DA*DB) buffer, sort by column carrying values, detect
segment boundaries, scatter-add into per-row slots.  Overflow (a row whose
true nnz exceeds the predicted capacity) is counted and returned so callers
can re-run with a bumped plan — the compiled-program analogue of realloc.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .csr import CSRDevice, COL_SENTINEL, expand_products, pad_row_ids
from .binning import ROUTE_SPA


class SpGEMMOut(NamedTuple):
    col: jax.Array       # (M, row_capacity) int32, COL_SENTINEL padded
    val: jax.Array       # (M, row_capacity) float32
    row_nnz: jax.Array   # (M,) int32 — true nnz per row (may exceed capacity)
    overflow: jax.Array  # scalar int32 — total entries dropped for capacity


class PanelSpgemmOut(NamedTuple):
    """Column-partitioned numeric-phase output (DESIGN.md §8).

    One compacted block per (bucket, panel): ``cols[i][p]`` is
    ``(bucket_rows, cap[i, p])`` int32 (COL_SENTINEL padded, ascending
    ABSOLUTE column ids inside panel ``p``'s range).  Panels partition the
    column space, so a row's full output is the panel blocks read in panel
    order — no cross-panel merge pass is needed; ``reassemble`` (or any
    COO sort) restores the single-matrix layout bitwise.
    """

    cols: tuple          # per bucket: tuple per panel (rows, cap_ip) int32
    vals: tuple          # per bucket: tuple per panel (rows, cap_ip) float32
    row_nnz: tuple       # per bucket: tuple per panel (rows,) int32 — true
                         # per-panel nnz (may exceed the panel capacity)
    overflow: jax.Array  # scalar int32 — entries dropped across all blocks


def gather_products(a: CSRDevice, b: CSRDevice, rows: jax.Array,
                    max_deg_a: int, max_deg_b: int,
                    rownnz_b: jax.Array | None = None):
    """Columns AND value-products of all intermediate products of ``rows``
    (value-carrying view of :func:`repro.core.csr.expand_products`)."""
    return expand_products(a, b, rows, max_deg_a, max_deg_b,
                           rownnz_b=rownnz_b, with_values=True)


def _accumulate_block(cols, vals, row_capacity: int):
    """Sort-merge accumulation for one block of rows."""
    order = jnp.argsort(cols, axis=-1)
    c_s = jnp.take_along_axis(cols, order, axis=-1)
    v_s = jnp.take_along_axis(vals, order, axis=-1)
    valid = c_s != COL_SENTINEL
    newseg = jnp.concatenate(
        [valid[:, :1],
         (c_s[:, 1:] != c_s[:, :-1]) & valid[:, 1:]], axis=-1)
    seg = jnp.cumsum(newseg.astype(jnp.int32), axis=-1) - 1       # distinct id
    row_nnz = seg[:, -1] + 1
    # scatter: invalid or overflowing slots go out of bounds (mode=drop)
    seg_sc = jnp.where(valid, seg, row_capacity)
    bs = cols.shape[0]
    rows_ix = jnp.broadcast_to(jnp.arange(bs)[:, None], seg_sc.shape)
    out_val = jnp.zeros((bs, row_capacity), jnp.float32).at[rows_ix, seg_sc].add(
        v_s, mode="drop")
    out_col = jnp.full((bs, row_capacity), COL_SENTINEL, jnp.int32).at[
        rows_ix, seg_sc].min(c_s, mode="drop")
    overflow = jnp.maximum(row_nnz - row_capacity, 0).sum()
    return out_col, out_val, row_nnz, overflow


def _dense_accumulate_block(cols, vals, ncols_b: int, row_capacity: int,
                            span: int = 0):
    """Dense-SPA accumulation for one block of rows (jnp path, DESIGN §5).

    Value products scatter-add into a dense accumulator; structural presence
    is tracked separately (a run summing to 0.0 is still an output entry,
    exactly as on the sort path), then both compact into the predicted
    ``row_capacity`` slots in ascending-column order — the same layout the
    sort path emits.  Sentinel-padded slots scatter out of range and are
    dropped.  With ``span`` (the planner's per-row column-extent bound) the
    accumulator covers only the pow2-padded extent, addressed relative to
    each row's minimum column — the banded/FEM lever of the SPA route.
    """
    from .binning import ceil_pow2
    bs = cols.shape[0]
    lo = None
    n = min(int(span), ncols_b) if span else ncols_b
    if span:
        from repro.kernels.accumulator import extent_relative
        cols, lo = extent_relative(cols)
        n = ceil_pow2(n)
    rows_ix = jnp.broadcast_to(jnp.arange(bs)[:, None], cols.shape)
    acc = jnp.zeros((bs, n), jnp.float32).at[rows_ix, cols].add(
        vals, mode="drop")
    present = jnp.zeros((bs, n), jnp.bool_).at[rows_ix, cols].set(
        True, mode="drop")
    return compact_dense(acc, present, row_capacity, col_offset=lo)


def compact_dense(acc, present, row_capacity: int, col_offset=None):
    """Dense accumulator (+ presence mask) → predicted-capacity buffers.

    Shared by the jnp SPA path and the Pallas SPA kernel wrapper: ascending
    columns, ``row_nnz`` = structural count (may exceed capacity), overflow
    slots dropped — bit-identical structure to the ESC compaction.
    ``col_offset`` (per-row int32) restores absolute column ids when the
    accumulator was addressed relative to each row's minimum column (the
    extent-relative layout of ``kernels.accumulator.spa_numeric_pallas``).
    """
    bs, n = acc.shape
    pres_i = present.astype(jnp.int32)
    seg = jnp.cumsum(pres_i, axis=-1) - 1
    seg_sc = jnp.where(present, seg, row_capacity)
    rows_ix = jnp.broadcast_to(jnp.arange(bs)[:, None], acc.shape)
    out_val = jnp.zeros((bs, row_capacity), jnp.float32).at[
        rows_ix, seg_sc].add(acc, mode="drop")
    col_ids = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :],
                               acc.shape)
    if col_offset is not None:
        col_ids = col_ids + col_offset[:, None].astype(jnp.int32)
    out_col = jnp.full((bs, row_capacity), COL_SENTINEL, jnp.int32).at[
        rows_ix, seg_sc].min(col_ids, mode="drop")
    row_nnz = seg[:, -1] + 1
    overflow = jnp.maximum(row_nnz - row_capacity, 0).sum()
    return out_col, out_val, row_nnz, overflow


def _blocked_rows(a: CSRDevice, b: CSRDevice, rows: jax.Array, body,
                  block_rows: int, row_capacity: int) -> SpGEMMOut:
    """Shared block/pad/slice scaffolding of the jnp numeric executors.

    Overflow is derived from the REAL rows' true nnz after slicing off the
    block padding — no closed-form correction inferred from the pad fill.
    (The previous correction assumed every pad row duplicates the *last*
    listed row; that holds for today's ``pad_row_ids`` but silently
    miscounts under any other fill contract — see its regression test.)
    """
    r = rows.shape[0]
    nblocks = -(-r // block_rows)
    pad_r = nblocks * block_rows
    row_ids = pad_row_ids(rows, block_rows).reshape(nblocks, block_rows)
    out_col, out_val, row_nnz, _ = jax.lax.map(body, row_ids)
    out_col = out_col.reshape(pad_r, row_capacity)[:r]
    out_val = out_val.reshape(pad_r, row_capacity)[:r]
    row_nnz = row_nnz.reshape(pad_r)[:r]
    overflow = jnp.maximum(row_nnz - row_capacity, 0).sum().astype(jnp.int32)
    return SpGEMMOut(out_col, out_val, row_nnz, overflow)


@functools.partial(jax.jit, static_argnames=("row_capacity", "max_deg_a",
                                             "max_deg_b", "block_rows"))
def spgemm_rows(a: CSRDevice, b: CSRDevice, rows: jax.Array, *,
                row_capacity: int, max_deg_a: int, max_deg_b: int,
                block_rows: int = 256) -> SpGEMMOut:
    """Numeric phase (ESC/sort route) for an explicit row-id list (one degree
    bucket, or all rows).  Output row ``i`` corresponds to ``rows[i]``."""
    rownnz_b = jnp.diff(b.rpt)

    def body(block):
        cols, vals, _ = gather_products(a, b, block, max_deg_a, max_deg_b,
                                        rownnz_b=rownnz_b)
        return _accumulate_block(cols, vals, row_capacity)

    return _blocked_rows(a, b, rows, body, block_rows, row_capacity)


@functools.partial(jax.jit, static_argnames=("row_capacity", "max_deg_a",
                                             "max_deg_b", "block_rows",
                                             "span"))
def spgemm_rows_spa(a: CSRDevice, b: CSRDevice, rows: jax.Array, *,
                    row_capacity: int, max_deg_a: int, max_deg_b: int,
                    block_rows: int = 256, span: int = 0) -> SpGEMMOut:
    """Numeric phase, dense-SPA route: same contract as :func:`spgemm_rows`
    (identical ``col``/``row_nnz``/``overflow``; ``val`` to float tolerance —
    the accumulation order differs).  ``span`` is the planner's bound on the
    rows' product-column extent (0 → full column space)."""
    rownnz_b = jnp.diff(b.rpt)

    def body(block):
        cols, vals, _ = gather_products(a, b, block, max_deg_a, max_deg_b,
                                        rownnz_b=rownnz_b)
        return _dense_accumulate_block(cols, vals, b.ncols, row_capacity,
                                       span)

    return _blocked_rows(a, b, rows, body, block_rows, row_capacity)


def spgemm(a: CSRDevice, b: CSRDevice, *, row_capacity: int,
           max_deg_a: int, max_deg_b: int, block_rows: int = 256) -> SpGEMMOut:
    """C = A·B numeric phase with predicted-capacity output buffers."""
    rows = jnp.arange(a.nrows, dtype=jnp.int32)
    return spgemm_rows(a, b, rows, row_capacity=row_capacity,
                       max_deg_a=max_deg_a, max_deg_b=max_deg_b,
                       block_rows=block_rows)


def routed_spgemm_rows(a: CSRDevice, b: CSRDevice, rows: jax.Array, *,
                       row_capacity: int, deg_a: int, deg_b: int,
                       block_rows: int, route: str = "esc", tile_n: int = 0,
                       n_tiles: int = 0, span: int = 0,
                       use_kernel: bool = False) -> SpGEMMOut:
    """One bucket's numeric phase on its planned accumulator route.

    THE per-bucket dispatch shared by :func:`spgemm_binned` and the
    plan/execute executors (``core.plan``) — single and distributed callers
    running a bucket through this one function is what makes their outputs
    interchangeable (identical ``col``/``row_nnz``/``overflow``; ``val`` to
    float tolerance across routes, see DESIGN.md §5/§6).
    """
    if use_kernel:
        from repro.kernels import ops as kops
        return SpGEMMOut(*kops.spgemm_numeric_routed(
            a, b, rows, max_deg_a=deg_a, max_deg_b=deg_b,
            row_capacity=row_capacity, block_rows=block_rows,
            route=route, tile_n=tile_n, n_tiles=n_tiles, span=span))
    if route == ROUTE_SPA:
        return spgemm_rows_spa(a, b, rows, row_capacity=row_capacity,
                               max_deg_a=deg_a, max_deg_b=deg_b,
                               block_rows=block_rows, span=span)
    return spgemm_rows(a, b, rows, row_capacity=row_capacity,
                       max_deg_a=deg_a, max_deg_b=deg_b,
                       block_rows=block_rows)


def pad_to_capacity(c: jax.Array, v: jax.Array,
                    cap_out: int) -> tuple[jax.Array, jax.Array]:
    """Widen a bucket's ``(rows, cap)`` col/val blocks to ``cap_out`` slots
    (sentinel/zero fill) — the shared output-assembly contract of
    :func:`spgemm_binned` and the ``core.plan`` executors."""
    cap = c.shape[1]
    if cap >= cap_out:
        return c, v
    c = jnp.concatenate(
        [c, jnp.full((c.shape[0], cap_out - cap), COL_SENTINEL, jnp.int32)],
        axis=1)
    v = jnp.concatenate(
        [v, jnp.zeros((v.shape[0], cap_out - cap), jnp.float32)], axis=1)
    return c, v


def spgemm_binned(a: CSRDevice, b: CSRDevice, plan, *,
                  alloc, use_kernel: bool = False) -> SpGEMMOut:
    """C = A·B numeric phase, bucket-iterated (DESIGN.md §4).

    ``plan`` is a ``core.binning.BinningPlan``; ``alloc`` is either an int
    (uniform row capacity — output bitwise-equal to :func:`spgemm` wherever
    every bucket runs the ESC route) or a ``predictor.BinnedAllocationPlan``
    (per-bucket capacities — smaller buffers, same values wherever neither
    path overflows).  Each bucket runs its planned accumulator route — ESC
    (sort) or dense-SPA — with identical ``col``/``row_nnz``/``overflow``
    and ``val`` to float tolerance (DESIGN.md §5).  With ``use_kernel`` the
    per-bucket pass is the routed Pallas dispatch in ``kernels.ops``.
    """
    if isinstance(alloc, (int, np.integer)):
        caps = [int(alloc)] * len(plan.buckets)
        cap_out = int(alloc)        # parity with spgemm even for empty plans
    else:
        caps = list(alloc.bucket_capacities)
        cap_out = max(caps) if caps else alloc.row_capacity
    if not plan.buckets:   # empty matrix: parity with the global path
        return SpGEMMOut(jnp.full((0, cap_out), COL_SENTINEL, jnp.int32),
                         jnp.zeros((0, cap_out), jnp.float32),
                         jnp.zeros((0,), jnp.int32), jnp.int32(0))
    parts_c, parts_v, parts_n = [], [], []
    overflow = jnp.int32(0)
    for bucket, cap in zip(plan.buckets, caps):
        if bucket.n_rows == 0:
            continue
        rows_d = jnp.asarray(bucket.rows)
        c, v, n, of = routed_spgemm_rows(
            a, b, rows_d, row_capacity=cap, deg_a=bucket.deg_a,
            deg_b=bucket.deg_b, block_rows=bucket.block_rows,
            route=bucket.route, tile_n=bucket.tile_n, n_tiles=bucket.n_tiles,
            span=bucket.span, use_kernel=use_kernel)
        c, v = pad_to_capacity(c, v, cap_out)
        parts_c.append(c)
        parts_v.append(v)
        parts_n.append(n.astype(jnp.int32))
        overflow = overflow + of.astype(jnp.int32)
    # buckets partition the rows: one concat + inverse permutation assembles
    # the output (no per-bucket full-array scatter copies)
    perm = plan.inverse_perm()
    return SpGEMMOut(jnp.concatenate(parts_c, axis=0)[perm],
                     jnp.concatenate(parts_v, axis=0)[perm],
                     jnp.concatenate(parts_n, axis=0)[perm],
                     overflow)


def dense_of(out: SpGEMMOut, ncols: int) -> jax.Array:
    """Densify (tests only)."""
    m, cap = out.col.shape
    valid = out.col != COL_SENTINEL
    safe = jnp.where(valid, out.col, 0)
    rows = jnp.broadcast_to(jnp.arange(m)[:, None], (m, cap))
    return jnp.zeros((m, ncols), jnp.float32).at[rows, safe].add(
        jnp.where(valid, out.val, 0.0))
