"""Numeric SpGEMM on device (JAX), allocated from the paper's prediction.

Flow (the paper's motivating use-case, Section I):
  1. ``flop_per_row``          — upper bound / load-balance info (Algorithm 1)
  2. ``proposed_predict``      — sampled-CR output-structure prediction (eq. 4)
  3. ``AllocationPlan``        — static output capacities from the prediction
  4. ``spgemm``  (this module) — row-wise numeric phase writing into the
                                  predicted-size buffers, overflow-reported.

The numeric accumulation mirrors the symbolic TPU adaptation: expand products
into a static (rows, DA*DB) buffer, sort by column carrying values, detect
segment boundaries, scatter-add into per-row slots.  Overflow (a row whose
true nnz exceeds the predicted capacity) is counted and returned so callers
can re-run with a bumped plan — the compiled-program analogue of realloc.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .csr import CSRDevice, COL_SENTINEL, pad_row_ids


class SpGEMMOut(NamedTuple):
    col: jax.Array       # (M, row_capacity) int32, COL_SENTINEL padded
    val: jax.Array       # (M, row_capacity) float32
    row_nnz: jax.Array   # (M,) int32 — true nnz per row (may exceed capacity)
    overflow: jax.Array  # scalar int32 — total entries dropped for capacity


def gather_products(a: CSRDevice, b: CSRDevice, rows: jax.Array,
                    max_deg_a: int, max_deg_b: int):
    """Columns AND value-products of all intermediate products of ``rows``."""
    deg_a = (a.rpt[rows + 1] - a.rpt[rows]).astype(jnp.int32)
    ia = jnp.arange(max_deg_a, dtype=jnp.int32)
    idx_a = jnp.clip(a.rpt[rows][:, None] + ia[None, :], 0, a.capacity - 1)
    valid_a = ia[None, :] < deg_a[:, None]
    ks = jnp.where(valid_a, a.col[idx_a], 0)
    av = jnp.where(valid_a, a.val[idx_a], 0.0)

    rownnz_b = jnp.diff(b.rpt)
    deg_b = jnp.where(valid_a, rownnz_b[ks], 0)
    ib = jnp.arange(max_deg_b, dtype=jnp.int32)
    idx_b = jnp.clip(b.rpt[ks][:, :, None] + ib[None, None, :], 0, b.capacity - 1)
    valid = valid_a[:, :, None] & (ib[None, None, :] < deg_b[:, :, None])
    cols = jnp.where(valid, b.col[idx_b], COL_SENTINEL)
    vals = jnp.where(valid, av[:, :, None] * b.val[idx_b], 0.0)
    s = rows.shape[0]
    f = max_deg_a * max_deg_b
    return cols.reshape(s, f), vals.reshape(s, f), valid.reshape(s, f)


def _accumulate_block(cols, vals, row_capacity: int):
    """Sort-merge accumulation for one block of rows."""
    order = jnp.argsort(cols, axis=-1)
    c_s = jnp.take_along_axis(cols, order, axis=-1)
    v_s = jnp.take_along_axis(vals, order, axis=-1)
    valid = c_s != COL_SENTINEL
    newseg = jnp.concatenate(
        [valid[:, :1],
         (c_s[:, 1:] != c_s[:, :-1]) & valid[:, 1:]], axis=-1)
    seg = jnp.cumsum(newseg.astype(jnp.int32), axis=-1) - 1       # distinct id
    row_nnz = seg[:, -1] + 1
    # scatter: invalid or overflowing slots go out of bounds (mode=drop)
    seg_sc = jnp.where(valid, seg, row_capacity)
    bs = cols.shape[0]
    rows_ix = jnp.broadcast_to(jnp.arange(bs)[:, None], seg_sc.shape)
    out_val = jnp.zeros((bs, row_capacity), jnp.float32).at[rows_ix, seg_sc].add(
        v_s, mode="drop")
    out_col = jnp.full((bs, row_capacity), COL_SENTINEL, jnp.int32).at[
        rows_ix, seg_sc].min(c_s, mode="drop")
    overflow = jnp.maximum(row_nnz - row_capacity, 0).sum()
    return out_col, out_val, row_nnz, overflow


@functools.partial(jax.jit, static_argnames=("row_capacity", "max_deg_a",
                                             "max_deg_b", "block_rows"))
def spgemm_rows(a: CSRDevice, b: CSRDevice, rows: jax.Array, *,
                row_capacity: int, max_deg_a: int, max_deg_b: int,
                block_rows: int = 256) -> SpGEMMOut:
    """Numeric phase for an explicit row-id list (one degree bucket, or all
    rows).  Output row ``i`` corresponds to ``rows[i]``."""
    r = rows.shape[0]
    nblocks = -(-r // block_rows)
    pad_r = nblocks * block_rows
    row_ids = pad_row_ids(rows, block_rows).reshape(nblocks, block_rows)

    def body(block):
        cols, vals, _ = gather_products(a, b, block, max_deg_a, max_deg_b)
        return _accumulate_block(cols, vals, row_capacity)

    out_col, out_val, row_nnz, overflow = jax.lax.map(body, row_ids)
    out_col = out_col.reshape(pad_r, row_capacity)[:r]
    out_val = out_val.reshape(pad_r, row_capacity)[:r]
    row_nnz = row_nnz.reshape(pad_r)[:r]
    # padded duplicate rows were counted in the per-block overflow sums
    pad_over = jnp.maximum(row_nnz[-1:] - row_capacity, 0) * (pad_r - r)
    return SpGEMMOut(out_col, out_val, row_nnz,
                     overflow.sum() - pad_over.sum())


def spgemm(a: CSRDevice, b: CSRDevice, *, row_capacity: int,
           max_deg_a: int, max_deg_b: int, block_rows: int = 256) -> SpGEMMOut:
    """C = A·B numeric phase with predicted-capacity output buffers."""
    rows = jnp.arange(a.nrows, dtype=jnp.int32)
    return spgemm_rows(a, b, rows, row_capacity=row_capacity,
                       max_deg_a=max_deg_a, max_deg_b=max_deg_b,
                       block_rows=block_rows)


def spgemm_binned(a: CSRDevice, b: CSRDevice, plan, *,
                  alloc, use_kernel: bool = False) -> SpGEMMOut:
    """C = A·B numeric phase, bucket-iterated (DESIGN.md §4).

    ``plan`` is a ``core.binning.BinningPlan``; ``alloc`` is either an int
    (uniform row capacity — output bitwise-equal to :func:`spgemm`) or a
    ``predictor.BinnedAllocationPlan`` (per-bucket capacities — smaller
    buffers, same values wherever neither path overflows).  With
    ``use_kernel`` each bucket routes through the Pallas numeric kernel
    (``kernels.spgemm_numeric``) at the bucket's degree bounds.
    """
    if isinstance(alloc, (int, np.integer)):
        caps = [int(alloc)] * len(plan.buckets)
        cap_out = int(alloc)        # parity with spgemm even for empty plans
    else:
        caps = list(alloc.bucket_capacities)
        cap_out = max(caps) if caps else alloc.row_capacity
    if not plan.buckets:   # empty matrix: parity with the global path
        return SpGEMMOut(jnp.full((0, cap_out), COL_SENTINEL, jnp.int32),
                         jnp.zeros((0, cap_out), jnp.float32),
                         jnp.zeros((0,), jnp.int32), jnp.int32(0))
    parts_c, parts_v, parts_n = [], [], []
    overflow = jnp.int32(0)
    for bucket, cap in zip(plan.buckets, caps):
        if bucket.n_rows == 0:
            continue
        rows_d = jnp.asarray(bucket.rows)
        if use_kernel:
            from repro.kernels import ops as kops
            c, v, n, of = kops.spgemm_numeric(
                a, b, rows_d, max_deg_a=bucket.deg_a, max_deg_b=bucket.deg_b,
                row_capacity=cap, block_rows=bucket.block_rows)
        else:
            c, v, n, of = spgemm_rows(
                a, b, rows_d, row_capacity=cap, max_deg_a=bucket.deg_a,
                max_deg_b=bucket.deg_b, block_rows=bucket.block_rows)
        if cap < cap_out:
            c = jnp.concatenate(
                [c, jnp.full((c.shape[0], cap_out - cap), COL_SENTINEL,
                             jnp.int32)], axis=1)
            v = jnp.concatenate(
                [v, jnp.zeros((v.shape[0], cap_out - cap), jnp.float32)],
                axis=1)
        parts_c.append(c)
        parts_v.append(v)
        parts_n.append(n.astype(jnp.int32))
        overflow = overflow + of.astype(jnp.int32)
    # buckets partition the rows: one concat + inverse permutation assembles
    # the output (no per-bucket full-array scatter copies)
    perm = plan.inverse_perm()
    return SpGEMMOut(jnp.concatenate(parts_c, axis=0)[perm],
                     jnp.concatenate(parts_v, axis=0)[perm],
                     jnp.concatenate(parts_n, axis=0)[perm],
                     overflow)


def dense_of(out: SpGEMMOut, ncols: int) -> jax.Array:
    """Densify (tests only)."""
    m, cap = out.col.shape
    valid = out.col != COL_SENTINEL
    safe = jnp.where(valid, out.col, 0)
    rows = jnp.broadcast_to(jnp.arange(m)[:, None], (m, cap))
    return jnp.zeros((m, ncols), jnp.float32).at[rows, safe].add(
        jnp.where(valid, out.val, 0.0))
