"""Numeric SpGEMM on device (JAX), allocated from the paper's prediction.

Flow (the paper's motivating use-case, Section I):
  1. ``flop_per_row``          — upper bound / load-balance info (Algorithm 1)
  2. ``proposed_predict``      — sampled-CR output-structure prediction (eq. 4)
  3. ``AllocationPlan``        — static output capacities from the prediction
  4. ``spgemm``  (this module) — row-wise numeric phase writing into the
                                  predicted-size buffers, overflow-reported.

The numeric accumulation mirrors the symbolic TPU adaptation: expand products
into a static (rows, DA*DB) buffer, sort by column carrying values, detect
segment boundaries, scatter-add into per-row slots.  Overflow (a row whose
true nnz exceeds the predicted capacity) is counted and returned so callers
can re-run with a bumped plan — the compiled-program analogue of realloc.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .csr import CSRDevice, COL_SENTINEL


class SpGEMMOut(NamedTuple):
    col: jax.Array       # (M, row_capacity) int32, COL_SENTINEL padded
    val: jax.Array       # (M, row_capacity) float32
    row_nnz: jax.Array   # (M,) int32 — true nnz per row (may exceed capacity)
    overflow: jax.Array  # scalar int32 — total entries dropped for capacity


def gather_products(a: CSRDevice, b: CSRDevice, rows: jax.Array,
                    max_deg_a: int, max_deg_b: int):
    """Columns AND value-products of all intermediate products of ``rows``."""
    deg_a = (a.rpt[rows + 1] - a.rpt[rows]).astype(jnp.int32)
    ia = jnp.arange(max_deg_a, dtype=jnp.int32)
    idx_a = jnp.clip(a.rpt[rows][:, None] + ia[None, :], 0, a.capacity - 1)
    valid_a = ia[None, :] < deg_a[:, None]
    ks = jnp.where(valid_a, a.col[idx_a], 0)
    av = jnp.where(valid_a, a.val[idx_a], 0.0)

    rownnz_b = jnp.diff(b.rpt)
    deg_b = jnp.where(valid_a, rownnz_b[ks], 0)
    ib = jnp.arange(max_deg_b, dtype=jnp.int32)
    idx_b = jnp.clip(b.rpt[ks][:, :, None] + ib[None, None, :], 0, b.capacity - 1)
    valid = valid_a[:, :, None] & (ib[None, None, :] < deg_b[:, :, None])
    cols = jnp.where(valid, b.col[idx_b], COL_SENTINEL)
    vals = jnp.where(valid, av[:, :, None] * b.val[idx_b], 0.0)
    s = rows.shape[0]
    f = max_deg_a * max_deg_b
    return cols.reshape(s, f), vals.reshape(s, f), valid.reshape(s, f)


def _accumulate_block(cols, vals, row_capacity: int):
    """Sort-merge accumulation for one block of rows."""
    order = jnp.argsort(cols, axis=-1)
    c_s = jnp.take_along_axis(cols, order, axis=-1)
    v_s = jnp.take_along_axis(vals, order, axis=-1)
    valid = c_s != COL_SENTINEL
    newseg = jnp.concatenate(
        [valid[:, :1],
         (c_s[:, 1:] != c_s[:, :-1]) & valid[:, 1:]], axis=-1)
    seg = jnp.cumsum(newseg.astype(jnp.int32), axis=-1) - 1       # distinct id
    row_nnz = seg[:, -1] + 1
    # scatter: invalid or overflowing slots go out of bounds (mode=drop)
    seg_sc = jnp.where(valid, seg, row_capacity)
    bs = cols.shape[0]
    rows_ix = jnp.broadcast_to(jnp.arange(bs)[:, None], seg_sc.shape)
    out_val = jnp.zeros((bs, row_capacity), jnp.float32).at[rows_ix, seg_sc].add(
        v_s, mode="drop")
    out_col = jnp.full((bs, row_capacity), COL_SENTINEL, jnp.int32).at[
        rows_ix, seg_sc].min(c_s, mode="drop")
    overflow = jnp.maximum(row_nnz - row_capacity, 0).sum()
    return out_col, out_val, row_nnz, overflow


@functools.partial(jax.jit, static_argnames=("row_capacity", "max_deg_a",
                                             "max_deg_b", "block_rows"))
def spgemm(a: CSRDevice, b: CSRDevice, *, row_capacity: int,
           max_deg_a: int, max_deg_b: int, block_rows: int = 256) -> SpGEMMOut:
    """C = A·B numeric phase with predicted-capacity output buffers."""
    m = a.nrows
    nblocks = -(-m // block_rows)
    pad_m = nblocks * block_rows
    row_ids = jnp.arange(pad_m, dtype=jnp.int32).reshape(nblocks, block_rows)
    row_ids = jnp.minimum(row_ids, m - 1)  # tail clamp; dup rows are sliced off

    def body(rows):
        cols, vals, _ = gather_products(a, b, rows, max_deg_a, max_deg_b)
        return _accumulate_block(cols, vals, row_capacity)

    out_col, out_val, row_nnz, overflow = jax.lax.map(body, row_ids)
    return SpGEMMOut(out_col.reshape(pad_m, row_capacity)[:m],
                     out_val.reshape(pad_m, row_capacity)[:m],
                     row_nnz.reshape(pad_m)[:m],
                     overflow.sum())


def dense_of(out: SpGEMMOut, ncols: int) -> jax.Array:
    """Densify (tests only)."""
    m, cap = out.col.shape
    valid = out.col != COL_SENTINEL
    safe = jnp.where(valid, out.col, 0)
    rows = jnp.broadcast_to(jnp.arange(m)[:, None], (m, cap))
    return jnp.zeros((m, ncols), jnp.float32).at[rows, safe].add(
        jnp.where(valid, out.val, 0.0))
