"""The paper's Section VI accuracy experiment: 625 test cases.

For every (A, B) pair of the 25-matrix suite (dimension-matched with the
paper's reshape rule) we compute, on the SAME sampled rows (the proposed
method 'utilizes the same information computed by the reference design'):

  e1 = (Z1* - Z)/Z   reference design        (eq. 2)
  ef = (F* - F)/F    symmetric FLOP predictor (eq. 3)
  e2 = (Z2* - Z)/Z   proposed sampled-CR      (eq. 4)
  e3 = (Z3* - Z)/Z   k-min-hash baseline      (Section III)

and verify the identity  e2 == (e1 - ef)/(1 + ef)  (eq. 5) per case.

Paper's results to compare against: mean |e1| = 8.12%, mean |e2| = 1.56%,
worst |e1| = 158%, worst |e2| = 25%, proposed better on 81.4% of cases,
corr(e1, ef) = 97.01%.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.sparse.formats import CSR
from repro.sparse import suite as suite_mod
from . import oracle

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "accuracy_625.json")


def run_case(a: CSR, b: CSR, seed: int, k_minhash: int = 64) -> dict:
    """One test case; expands the sampled product stream exactly once."""
    floprc, total_flop = oracle.flop_per_row(a, b)
    _, z_exact = oracle.exact_structure(a, b)
    rows = oracle.sample_rows(a.nrows, seed)
    p = rows.size / a.nrows

    owner, col = oracle.expand_products(a, b, rows)
    keys = owner * np.int64(b.ncols) + col
    z_star = int(np.unique(keys).size)                     # exact sampled NNZ
    f_star = int(floprc[rows].sum())                       # sampled FLOP

    z1 = z_star / p                                        # reference design
    f_pred = f_star / p                                    # symmetric F*
    r_star = f_star / max(z_star, 1)                       # sampled CR
    z2 = total_flop / r_star                               # proposed

    hv = np.unique(oracle._hash01(keys, seed))             # k-min-hash baseline
    if hv.size <= k_minhash:
        z3s = float(hv.size)
    else:
        z3s = k_minhash / hv[k_minhash - 1]
    z3 = z3s / p

    e1 = (z1 - z_exact) / z_exact
    ef = (f_pred - total_flop) / total_flop
    e2 = (z2 - z_exact) / z_exact
    e3 = (z3 - z_exact) / z_exact
    # eq. 5 identity (must hold to float precision)
    e2_eq5 = (e1 - ef) / (1 + ef)
    return dict(
        sample_num=int(rows.size), flop=int(total_flop), nnz=int(z_exact),
        cr=total_flop / z_exact, e1=e1, ef=ef, e2=e2, e3=e3,
        eq5_resid=abs(e2 - e2_eq5),
    )


def aggregate(cases: list[dict]) -> dict:
    e1 = np.array([c["e1"] for c in cases])
    ef = np.array([c["ef"] for c in cases])
    e2 = np.array([c["e2"] for c in cases])
    e3 = np.array([c["e3"] for c in cases])
    better = np.abs(e2) < np.abs(e1)
    corr = float(np.corrcoef(e1, ef)[0, 1])
    return dict(
        n_cases=len(cases),
        mean_abs_e1=float(np.abs(e1).mean()), worst_abs_e1=float(np.abs(e1).max()),
        mean_abs_ef=float(np.abs(ef).mean()), worst_abs_ef=float(np.abs(ef).max()),
        mean_abs_e2=float(np.abs(e2).mean()), worst_abs_e2=float(np.abs(e2).max()),
        mean_abs_e3=float(np.abs(e3).mean()), worst_abs_e3=float(np.abs(e3).max()),
        proposed_better_frac=float(better.mean()),
        corr_e1_ef=corr,
        max_eq5_resid=float(max(c["eq5_resid"] for c in cases)),
        paper=dict(mean_abs_e1=0.0812, mean_abs_e2=0.0156, worst_abs_e1=1.58,
                   worst_abs_e2=0.25, proposed_better_frac=0.814, corr_e1_ef=0.9701),
    )


def run_all(seed: int = 2022, out_path: str | None = None, names=None, verbose=True) -> dict:
    out_path = out_path or os.path.abspath(ARTIFACT)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    cases = []
    t0 = time.time()
    for i, (na, nb, a, b) in enumerate(suite_mod.iter_cases(names)):
        c = run_case(a, b, seed=seed + i)
        c["A"], c["B"] = na, nb
        cases.append(c)
        if verbose and (i + 1) % 25 == 0:
            agg = aggregate(cases)
            print(f"[{i+1:4d}] {time.time()-t0:7.1f}s  mean|e1|={agg['mean_abs_e1']*100:.2f}% "
                  f"mean|e2|={agg['mean_abs_e2']*100:.2f}%", flush=True)
    result = dict(aggregate=aggregate(cases), cases=cases, seed=seed)
    with open(out_path + ".tmp", "w") as f:
        json.dump(result, f)
    os.replace(out_path + ".tmp", out_path)  # atomic commit
    if verbose:
        print(json.dumps(result["aggregate"], indent=2))
    return result


if __name__ == "__main__":
    run_all()
