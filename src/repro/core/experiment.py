"""The paper's Section VI accuracy experiment: 625 test cases.

For every (A, B) pair of the 25-matrix suite (dimension-matched with the
paper's reshape rule) we compute, on the SAME sampled rows (the proposed
method 'utilizes the same information computed by the reference design'):

  e1 = (Z1* - Z)/Z   reference design        (eq. 2)
  ef = (F* - F)/F    symmetric FLOP predictor (eq. 3)
  e2 = (Z2* - Z)/Z   proposed sampled-CR      (eq. 4)
  e3 = (Z3* - Z)/Z   k-min-hash baseline      (Section III)

and verify the identity  e2 == (e1 - ef)/(1 + ef)  (eq. 5) per case.

Paper's results to compare against: mean |e1| = 8.12%, mean |e2| = 1.56%,
worst |e1| = 158%, worst |e2| = 25%, proposed better on 81.4% of cases,
corr(e1, ef) = 97.01%.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.sparse.formats import CSR
from repro.sparse import suite as suite_mod
from . import oracle

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "accuracy_625.json")
SUBSET_BASELINE = os.path.join(os.path.dirname(ARTIFACT),
                               "accuracy_subset_baseline.json")
SUBSET_PER_FAMILY_PAIR = 3      # 5 families × 5 families × 3 = 75 cases


def run_case(a: CSR, b: CSR, seed: int, k_minhash: int = 64) -> dict:
    """One test case; expands the sampled product stream exactly once."""
    floprc, total_flop = oracle.flop_per_row(a, b)
    _, z_exact = oracle.exact_structure(a, b)
    rows = oracle.sample_rows(a.nrows, seed)
    p = rows.size / a.nrows

    owner, col = oracle.expand_products(a, b, rows)
    keys = owner * np.int64(b.ncols) + col
    z_star = int(np.unique(keys).size)                     # exact sampled NNZ
    f_star = int(floprc[rows].sum())                       # sampled FLOP

    z1 = z_star / p                                        # reference design
    f_pred = f_star / p                                    # symmetric F*
    r_star = f_star / max(z_star, 1)                       # sampled CR
    z2 = total_flop / r_star                               # proposed

    hv = np.unique(oracle._hash01(keys, seed))             # k-min-hash baseline
    if hv.size <= k_minhash:
        z3s = float(hv.size)
    else:
        z3s = k_minhash / hv[k_minhash - 1]
    z3 = z3s / p

    e1 = (z1 - z_exact) / z_exact
    ef = (f_pred - total_flop) / total_flop
    e2 = (z2 - z_exact) / z_exact
    e3 = (z3 - z_exact) / z_exact
    # eq. 5 identity (must hold to float precision)
    e2_eq5 = (e1 - ef) / (1 + ef)
    return dict(
        sample_num=int(rows.size), flop=int(total_flop), nnz=int(z_exact),
        cr=total_flop / z_exact, e1=e1, ef=ef, e2=e2, e3=e3,
        eq5_resid=abs(e2 - e2_eq5),
    )


def aggregate(cases: list[dict]) -> dict:
    e1 = np.array([c["e1"] for c in cases])
    ef = np.array([c["ef"] for c in cases])
    e2 = np.array([c["e2"] for c in cases])
    e3 = np.array([c["e3"] for c in cases])
    better = np.abs(e2) < np.abs(e1)
    corr = float(np.corrcoef(e1, ef)[0, 1])
    return dict(
        n_cases=len(cases),
        mean_abs_e1=float(np.abs(e1).mean()), worst_abs_e1=float(np.abs(e1).max()),
        mean_abs_ef=float(np.abs(ef).mean()), worst_abs_ef=float(np.abs(ef).max()),
        mean_abs_e2=float(np.abs(e2).mean()), worst_abs_e2=float(np.abs(e2).max()),
        mean_abs_e3=float(np.abs(e3).mean()), worst_abs_e3=float(np.abs(e3).max()),
        proposed_better_frac=float(better.mean()),
        corr_e1_ef=corr,
        max_eq5_resid=float(max(c["eq5_resid"] for c in cases)),
        paper=dict(mean_abs_e1=0.0812, mean_abs_e2=0.0156, worst_abs_e1=1.58,
                   worst_abs_e2=0.25, proposed_better_frac=0.814, corr_e1_ef=0.9701),
    )


# --------------------------------------------------------------------------- #
# Deterministic regression subset (ISSUE 4): 3 cases per ordered family pair.
# The accuracy gate CI runs per push — the full 625 sweep stays a slow test.
# --------------------------------------------------------------------------- #
def subset_pairs() -> list[tuple[str, str]]:
    """75 deterministic (A, B) suite pairs: for each ordered family pair,
    3 evenly-spaced picks from the full product of that pair's matrices."""
    fams: dict[str, list[str]] = {}
    for e in suite_mod.SUITE:
        fams.setdefault(e.family, []).append(e.name)
    pairs = []
    for fa in fams:
        for fb in fams:
            prod = [(na, nb) for na in fams[fa] for nb in fams[fb]]
            for k in range(SUBSET_PER_FAMILY_PAIR):
                pairs.append(prod[(k * len(prod)) // SUBSET_PER_FAMILY_PAIR])
    return pairs


def run_subset(seed: int = 2022) -> dict:
    """Run the regression subset with the SAME per-case seeds as the full
    sweep (``seed + 625-enumeration-index``), so each subset case reproduces
    its counterpart in :func:`run_all`."""
    names = [e.name for e in suite_mod.SUITE]
    cases = []
    for na, nb in subset_pairs():
        i = names.index(na) * len(names) + names.index(nb)
        from repro.sparse.formats import match_dims
        am, bm = match_dims(suite_mod.get_matrix(na),
                            suite_mod.get_matrix(nb))
        c = run_case(am, bm, seed=seed + i)
        c["A"], c["B"] = na, nb
        cases.append(c)
    return dict(aggregate=aggregate(cases), cases=cases, seed=seed)


def write_subset_baseline(out_path: str | None = None) -> dict:
    """Generate + commit the accuracy-regression baseline artifact: per-case
    errors, aggregates, and the pinned thresholds the CI gate enforces
    (margins absorb RNG-stream drift across numpy versions)."""
    out_path = os.path.abspath(out_path or SUBSET_BASELINE)
    res = run_subset()
    agg = res["aggregate"]
    res["pinned"] = dict(
        max_mean_abs_e2=round(max(agg["mean_abs_e2"] * 1.25, 0.005), 6),
        max_worst_abs_e2=round(max(agg["worst_abs_e2"] * 1.5, 0.02), 6),
        max_case_abs_e2_drift=0.05,
    )
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path + ".tmp", "w") as f:
        json.dump(res, f, indent=1, sort_keys=True)
        f.write("\n")
    os.replace(out_path + ".tmp", out_path)
    return res


def run_all(seed: int = 2022, out_path: str | None = None, names=None, verbose=True) -> dict:
    out_path = out_path or os.path.abspath(ARTIFACT)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    cases = []
    t0 = time.time()
    for i, (na, nb, a, b) in enumerate(suite_mod.iter_cases(names)):
        c = run_case(a, b, seed=seed + i)
        c["A"], c["B"] = na, nb
        cases.append(c)
        if verbose and (i + 1) % 25 == 0:
            agg = aggregate(cases)
            print(f"[{i+1:4d}] {time.time()-t0:7.1f}s  mean|e1|={agg['mean_abs_e1']*100:.2f}% "
                  f"mean|e2|={agg['mean_abs_e2']*100:.2f}%", flush=True)
    result = dict(aggregate=aggregate(cases), cases=cases, seed=seed)
    with open(out_path + ".tmp", "w") as f:
        json.dump(result, f)
    os.replace(out_path + ".tmp", out_path)  # atomic commit
    if verbose:
        print(json.dumps(result["aggregate"], indent=2))
    return result


if __name__ == "__main__":
    import sys
    if "--subset-baseline" in sys.argv:
        res = write_subset_baseline()
        print(json.dumps(res["aggregate"], indent=2))
        print(json.dumps(res["pinned"], indent=2))
    else:
        run_all()
