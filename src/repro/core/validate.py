"""Host-side CSR operand validation (DESIGN.md §9).

``validate_csr`` checks every invariant the kernels silently assume —
``rpt`` monotonicity and length, column bounds and intra-row sortedness /
duplicates, NaN/Inf values, dtype contracts — and raises a pinpointed
:class:`~repro.core.errors.OperandValidationError` instead of letting a
malformed operand produce garbage output or an opaque XLA crash deep in a
jitted executor.

Wired into ``CSR.from_coo`` / ``from_dense`` (opt-out via ``validate=
False``), ``plan_spgemm`` and ``SpgemmPlan.to_device``.  All checks are
vectorized numpy passes, O(nnz) — the same order as the host work planning
already does (structural sketch, FLOP counting).
"""
from __future__ import annotations

import numpy as np

from .errors import OperandValidationError


def _row_of(rpt: np.ndarray, entry: int) -> int:
    """Row owning flat entry index ``entry`` (for pinpointed errors)."""
    return int(np.searchsorted(rpt, entry, side="right") - 1)


def validate_csr(m, *, name: str = "operand", allow_duplicates: bool = False,
                 check_values: bool = True) -> None:
    """Validate one host CSR operand; raise ``OperandValidationError`` with
    the offending field and first bad row/entry in ``context`` on the first
    violated invariant.

    ``allow_duplicates`` permits repeated columns within a row (a
    ``from_coo(dedup=False)`` matrix is allowed to carry them); sortedness
    is still required.  ``check_values=False`` skips the NaN/Inf scan for
    callers whose values are allowed to be non-finite.
    """
    def fail(msg: str, **ctx):
        raise OperandValidationError(f"{name}: {msg}", operand=name, **ctx)

    shape = getattr(m, "shape", None)
    if shape is None or len(shape) != 2 or shape[0] < 0 or shape[1] < 0:
        fail(f"shape {shape!r} is not a valid 2-D matrix shape",
             field="shape", observed=list(shape) if shape else None)
    nrows, ncols = int(shape[0]), int(shape[1])

    rpt = np.asarray(m.rpt)
    col = np.asarray(m.col)
    val = np.asarray(m.val)
    if rpt.ndim != 1 or not np.issubdtype(rpt.dtype, np.integer):
        fail(f"rpt must be a 1-D integer array, got ndim={rpt.ndim} "
             f"dtype={rpt.dtype}", field="rpt")
    if rpt.size != nrows + 1:
        fail(f"rpt length {rpt.size} != nrows+1 = {nrows + 1}",
             field="rpt", observed=int(rpt.size), planned=nrows + 1)
    if int(rpt[0]) != 0:
        fail(f"rpt[0] must be 0, got {int(rpt[0])}", field="rpt", index=0,
             observed=int(rpt[0]))
    drop = np.flatnonzero(np.diff(rpt) < 0)
    if drop.size:
        r = int(drop[0])
        fail(f"rpt not monotone at row {r}: {int(rpt[r])} -> "
             f"{int(rpt[r + 1])}", field="rpt", row=r,
             observed=int(rpt[r + 1]))
    nnz = int(rpt[-1])
    if col.ndim != 1 or not np.issubdtype(col.dtype, np.integer):
        fail(f"col must be a 1-D integer array, got ndim={col.ndim} "
             f"dtype={col.dtype}", field="col")
    if col.size != nnz:
        fail(f"col length {col.size} != rpt[-1] = {nnz}", field="col",
             observed=int(col.size), planned=nnz)
    if val.ndim != 1 or not np.issubdtype(val.dtype, np.floating):
        fail(f"val must be a 1-D float array, got ndim={val.ndim} "
             f"dtype={val.dtype}", field="val")
    if val.size != nnz:
        fail(f"val length {val.size} != rpt[-1] = {nnz}", field="val",
             observed=int(val.size), planned=nnz)
    if nnz:
        bad = np.flatnonzero((col < 0) | (col >= ncols))
        if bad.size:
            e = int(bad[0])
            fail(f"col[{e}] = {int(col[e])} out of range [0, {ncols}) "
                 f"(row {_row_of(rpt, e)})", field="col", index=e,
                 row=_row_of(rpt, e), observed=int(col[e]), planned=ncols)
        # intra-row order: col must ascend within a row (strictly unless
        # duplicates are allowed); violations at row boundaries are fine
        d = np.diff(col.astype(np.int64))
        interior = np.ones(max(0, nnz - 1), dtype=bool)
        bnd = np.asarray(rpt[1:-1], dtype=np.int64)
        bnd = bnd[(bnd > 0) & (bnd < nnz)]  # empty rows repeat 0 / nnz
        interior[bnd - 1] = False           # last entry of each row
        bad = np.flatnonzero(interior &
                             ((d < 0) if allow_duplicates else (d <= 0)))
        if bad.size:
            e = int(bad[0])
            kind = "unsorted" if col[e + 1] < col[e] else "duplicate"
            fail(f"{kind} columns in row {_row_of(rpt, e)}: "
                 f"col[{e}]={int(col[e])}, col[{e + 1}]={int(col[e + 1])}",
                 field="col", index=e + 1, row=_row_of(rpt, e),
                 observed=int(col[e + 1]))
        if check_values:
            bad = np.flatnonzero(~np.isfinite(val))
            if bad.size:
                e = int(bad[0])
                fail(f"non-finite val[{e}] = {val[e]} "
                     f"(row {_row_of(rpt, e)})", field="val", index=e,
                     row=_row_of(rpt, e), observed=repr(float(val[e])))


def validate_pair(a, b) -> None:
    """Validate an SpGEMM operand pair, including A·B dimension compatibility."""
    validate_csr(a, name="a")
    validate_csr(b, name="b")
    if a.shape[1] != b.shape[0]:
        raise OperandValidationError(
            f"operand shapes {a.shape} x {b.shape} are incompatible for "
            "A·B (a.ncols must equal b.nrows)", operand="pair",
            field="shape", observed=list(a.shape) + list(b.shape))
