"""Algorithm 1 on device: FLOP per output row (the upper-bound method), in JAX.

floprC[i] = sum_{j in [A.rpt[i], A.rpt[i+1])} ( B.rpt[A.col[j]+1] - B.rpt[A.col[j]] )

The nonzero→row map is recovered with a searchsorted over A.rpt (O(cap log M),
fully vectorized), then a scatter-add builds floprC.  This is also the ref
oracle for the Pallas ``flop_per_row`` kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .csr import CSRDevice


def flop_per_row(a: CSRDevice, b: CSRDevice) -> tuple[jax.Array, jax.Array]:
    """Returns (floprC int32 (M,), total_flop int64-ish int32 scalar)."""
    assert a.ncols == b.nrows, (a.shape, b.shape)
    cap = a.capacity
    rownnz_b = jnp.diff(b.rpt)  # (K,)
    pos = jnp.arange(cap, dtype=jnp.int32)
    valid = pos < a.nnz
    safe_col = jnp.where(valid, a.col, 0).astype(jnp.int32)
    contrib = jnp.where(valid, rownnz_b[safe_col], 0)
    # row of each nonzero: searchsorted right on rpt, minus one
    row_of_nnz = jnp.searchsorted(a.rpt, pos, side="right").astype(jnp.int32) - 1
    row_of_nnz = jnp.clip(row_of_nnz, 0, a.nrows - 1)
    floprc = jnp.zeros(a.nrows, dtype=jnp.int32).at[row_of_nnz].add(
        contrib, mode="drop")
    # int32 total: fine below 2^31 products; callers at larger scale chunk rows.
    return floprc, jnp.sum(floprc)
