"""Degree-aware row binning — the execution planner for both phases.

Motivation (DESIGN.md §4): the TPU adaptation expands each processed row into
a static ``(rows, DA·DB)`` gather/sort buffer where ``DA``/``DB`` are the
*global* max row degrees.  One hub row in a power-law matrix therefore
inflates the buffer quadratically for **every** row.  The standard SpGEMM fix
(Liu & Vinter, arXiv:1504.05022) is to bucket rows by the size of their
intermediate product set and run each bucket with buffers sized for *that*
bucket.

This module is the host-side planner (launch-time numpy, like
``core.partition``):

  * every output row ``i`` gets a width ``w_i = max(1, deg_a_i · dbmax_i)``
    where ``dbmax_i`` is the largest B-row degree among the B rows the row
    references — the exact lane count its gather/sort buffer needs;
  * rows are partitioned into pow2 buckets by ``ceil_pow2(w_i)``; buckets
    with fewer than ``min_rows`` rows are coalesced upward so tiny buckets
    don't fragment the grid into many kernel launches;
  * each bucket carries a static plan ``(rows, deg_a, deg_b, block_rows)``:
    ``deg_a``/``deg_b`` are the bucket's exact max degrees by default
    (``deg_align > 1`` opts into quantized bounds, see :func:`round_deg`) and
    ``block_rows`` is chosen so ``block_rows · next_pow2(deg_a·deg_b)`` stays
    under ``lane_budget`` (the VMEM envelope of the Pallas kernels).

Compile-cache contract: the device executors are ``jax.jit``-cached on the
bucket's static shapes — ``RowBucket.signature`` (= the static argnames)
*plus* the traced shapes, of which the bucket's row count is the one that
varies.  Two plans share a bucket's compiled program iff the signature AND
the bucket population match (padding populations to coarser sizes to raise
hit rates is a possible future knob); ``BinningPlan.signatures()`` exposes
the static part so callers (and tests) can check signature-level overlap.
"""
from __future__ import annotations

import dataclasses

import numpy as np

DEFAULT_LANE_BUDGET = 1 << 17   # lanes per kernel block: BS·F2 ≤ budget
DEFAULT_MAX_BLOCK_ROWS = 256
DEFAULT_MIN_ROWS = 32           # coalesce buckets smaller than this


def ceil_pow2(n: int) -> int:
    """Smallest power of two ≥ max(1, n)."""
    return 1 << max(0, (int(n) - 1).bit_length())


def round_deg(d: int, align: int = 1) -> int:
    """Degree bound rounding.  ``align=1`` keeps the exact bucket maximum —
    binned lanes are then ≤ global lanes for every row, by construction.
    Larger ``align`` quantizes (pow2 below ``align``, then multiples of it),
    trading ≤ ~1/align buffer inflation for a smaller signature set that
    jit-cache-shares across differently-shaped matrices."""
    d = max(1, int(d))
    if align <= 1:
        return d
    if d <= align:
        return ceil_pow2(d)
    return ((d + align - 1) // align) * align


@dataclasses.dataclass(frozen=True)
class RowBucket:
    """One degree bucket: static shapes + the row ids that run under them."""

    rows: np.ndarray      # int32 (n,) output-row ids, ascending
    deg_a: int            # bound on A-row degree within the bucket
    deg_b: int            # bound on referenced-B-row degree
    block_rows: int       # grid block height for this bucket's kernels

    @property
    def n_rows(self) -> int:
        return int(self.rows.size)

    @property
    def width(self) -> int:
        """Gather-buffer lanes per row (before kernel pow2 rounding)."""
        return self.deg_a * self.deg_b

    @property
    def lanes(self) -> int:
        """Total expanded-buffer lanes this bucket processes."""
        return self.n_rows * self.width

    @property
    def signature(self) -> tuple[int, int, int]:
        """The static shape tuple device executors specialize on."""
        return (self.deg_a, self.deg_b, self.block_rows)


@dataclasses.dataclass(frozen=True)
class BinningPlan:
    """Partition of all output rows into degree buckets."""

    buckets: tuple[RowBucket, ...]
    nrows: int
    global_deg_a: int         # the global-pad bounds the plan replaces
    global_deg_b: int
    row_bucket: np.ndarray    # int32 (nrows,) row → bucket index

    @property
    def lanes(self) -> int:
        """Expanded-buffer lanes processed by the binned pipeline."""
        return sum(b.lanes for b in self.buckets)

    @property
    def global_lanes(self) -> int:
        """Lanes the global-pad pipeline processes for the same rows."""
        return self.nrows * max(1, self.global_deg_a * self.global_deg_b)

    @property
    def lane_reduction(self) -> float:
        """How many× fewer lanes the binned pipeline touches (≥ 1 good)."""
        return self.global_lanes / max(1, self.lanes)

    def signatures(self) -> tuple[tuple[int, int, int], ...]:
        """Sorted unique bucket signatures — the compile-cache key set."""
        return tuple(sorted({b.signature for b in self.buckets}))

    def inverse_perm(self) -> np.ndarray:
        """Permutation restoring row-id order from bucket-concatenation order.

        Buckets partition the rows, so ``concat(per-bucket results)[perm]``
        assembles a full per-row array without per-bucket scatter copies —
        the shared assembly idiom of the binned executors."""
        return np.argsort(
            np.concatenate([b.rows for b in self.buckets])
            if self.buckets else np.zeros(0, np.int32), kind="stable")

    def subset(self, rows: np.ndarray) -> list[np.ndarray]:
        """Bucket an arbitrary row list (e.g. the sampled rows) under this
        plan — entry ``i`` holds the rows of ``rows`` that live in bucket
        ``i`` (duplicates preserved: sampling is with replacement)."""
        rows = np.asarray(rows, dtype=np.int64)
        which = self.row_bucket[rows]
        return [np.ascontiguousarray(rows[which == i].astype(np.int32))
                for i in range(len(self.buckets))]

    def stats(self) -> dict:
        return dict(
            num_buckets=len(self.buckets),
            lanes_binned=self.lanes,
            lanes_global=self.global_lanes,
            lane_reduction=round(self.lane_reduction, 3),
            signatures=[list(s) for s in self.signatures()],
            bucket_rows=[b.n_rows for b in self.buckets],
            bucket_widths=[b.width for b in self.buckets],
        )


def _pick_block_rows(width: int, lane_budget: int, max_block_rows: int) -> int:
    """Largest pow2 block height with block·F2 lanes under the VMEM budget."""
    f2 = ceil_pow2(width)
    fit = max(1, lane_budget // f2)
    blk = 1 << (fit.bit_length() - 1)          # floor to pow2
    return int(max(1, min(max_block_rows, blk)))


def row_widths(a_rpt: np.ndarray, a_col: np.ndarray,
               rownnz_b: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-output-row (deg_a, dbmax, width) from host CSR index arrays."""
    a_rpt = np.asarray(a_rpt, dtype=np.int64)
    a_col = np.asarray(a_col, dtype=np.int64)
    rownnz_b = np.asarray(rownnz_b, dtype=np.int64)
    m = a_rpt.size - 1
    nnz = int(a_rpt[-1])
    deg_a = np.diff(a_rpt)
    # max referenced-B degree per row: maximum.reduceat over the CSR slices
    per_nnz = rownnz_b[np.clip(a_col[:nnz], 0, rownnz_b.size - 1)]
    dbmax = np.zeros(m, dtype=np.int64)
    nonempty = deg_a > 0
    if nnz:
        starts = a_rpt[:-1][nonempty]
        dbmax[nonempty] = np.maximum.reduceat(per_nnz, starts)
    width = np.maximum(1, deg_a * dbmax)
    return deg_a, dbmax, width


def build_plan(a, b, *, lane_budget: int = DEFAULT_LANE_BUDGET,
               max_block_rows: int = DEFAULT_MAX_BLOCK_ROWS,
               min_rows: int = DEFAULT_MIN_ROWS,
               deg_align: int = 1) -> BinningPlan:
    """Plan the binned execution of ``C = A·B``.

    ``a``/``b`` may be host ``CSR`` or device ``CSRDevice`` — only the int
    index arrays are read (pulled to host; planning is a launch-time step).
    """
    a_rpt = np.asarray(a.rpt)
    a_col = np.asarray(a.col)
    b_rpt = np.asarray(b.rpt)
    rownnz_b = np.diff(b_rpt.astype(np.int64))
    deg_a, dbmax, width = row_widths(a_rpt, a_col, rownnz_b)
    m = deg_a.size

    # pow2 bucket key per row → ascending width groups (≤ ~log2(max_width))
    key = np.ceil(np.log2(np.maximum(width, 1))).astype(np.int64)
    order = np.argsort(key, kind="stable")
    sorted_key = key[order]
    _, starts_u, counts = np.unique(sorted_key, return_index=True,
                                    return_counts=True)
    groups = [order[s0:s0 + c] for s0, c in zip(starts_u, counts)]

    def bounds(ids):
        da = round_deg(int(deg_a[ids].max()), deg_align) if ids.size else 1
        db = round_deg(int(dbmax[ids].max()), deg_align) if ids.size else 1
        return da, db

    # Coalesce, ascending, and ONLY ever upward: a small group rides along
    # with the next larger-width bucket (a few rows pay a wider buffer).
    # Never merge downward — pulling one hub bucket into a big small-width
    # group would re-inflate every row to hub width, which is exactly the
    # pathology binning exists to remove.  Adjacent groups whose degree
    # bounds coincide merge for free (same compiled program either way).
    merged: list[np.ndarray] = []
    carry: np.ndarray | None = None
    for ids in groups:
        if carry is not None:
            ids = np.concatenate([carry, ids])
            carry = None
        if merged and bounds(np.concatenate([merged[-1], ids])) == bounds(merged[-1]):
            merged[-1] = np.concatenate([merged[-1], ids])
        elif ids.size < min_rows:
            carry = ids
        else:
            merged.append(ids)
    if carry is not None:
        if merged and bounds(np.concatenate([merged[-1], carry])) == bounds(merged[-1]):
            merged[-1] = np.concatenate([merged[-1], carry])
        else:
            merged.append(carry)        # trailing hub bucket stays isolated

    buckets = []
    row_bucket = np.zeros(m, dtype=np.int32)
    for i, ids in enumerate(merged):
        ids = np.sort(ids).astype(np.int32)
        da, db = bounds(ids)
        blk = _pick_block_rows(da * db, lane_budget, max_block_rows)
        buckets.append(RowBucket(rows=ids, deg_a=da, deg_b=db, block_rows=blk))
        row_bucket[ids] = i

    gda = int(deg_a.max()) if m else 1
    gdb = int(rownnz_b.max()) if rownnz_b.size else 1
    return BinningPlan(buckets=tuple(buckets), nrows=m,
                       global_deg_a=max(1, gda), global_deg_b=max(1, gdb),
                       row_bucket=row_bucket)
