"""Degree-aware row binning — the execution planner for both phases.

Motivation (DESIGN.md §4): the TPU adaptation expands each processed row into
a static ``(rows, DA·DB)`` gather/sort buffer where ``DA``/``DB`` are the
*global* max row degrees.  One hub row in a power-law matrix therefore
inflates the buffer quadratically for **every** row.  The standard SpGEMM fix
(Liu & Vinter, arXiv:1504.05022) is to bucket rows by the size of their
intermediate product set and run each bucket with buffers sized for *that*
bucket.

This module is the host-side planner (launch-time numpy, like
``core.partition``):

  * every output row ``i`` gets a width ``w_i = max(1, deg_a_i · dbmax_i)``
    where ``dbmax_i`` is the largest B-row degree among the B rows the row
    references — the exact lane count its gather/sort buffer needs;
  * rows are partitioned into pow2 buckets by ``ceil_pow2(w_i)``; buckets
    with fewer than ``min_rows`` rows are coalesced upward so tiny buckets
    don't fragment the grid into many kernel launches;
  * each bucket carries a static plan ``(rows, deg_a, deg_b, block_rows)``:
    ``deg_a``/``deg_b`` are the bucket's exact max degrees by default
    (``deg_align > 1`` opts into quantized bounds, see :func:`round_deg`) and
    ``block_rows`` is chosen so ``block_rows · next_pow2(deg_a·deg_b)`` stays
    under ``lane_budget`` (the VMEM envelope of the Pallas kernels);
  * each bucket is additionally stamped with an accumulator ``route``
    (DESIGN.md §5): ``"esc"`` — the bitonic sort backend — or ``"spa"`` —
    bitmask-popcount (symbolic) / dense column-tiled accumulator (numeric) —
    chosen at plan time by the :func:`route_costs` model so the executors
    dispatch with zero runtime branching.

Compile-cache contract: the device executors are ``jax.jit``-cached on the
bucket's static shapes — ``RowBucket.signature`` (= the static argnames)
*plus* the traced shapes, of which the bucket's row count is the one that
varies.  Two plans share a bucket's compiled program iff the signature AND
the bucket population match.  Padding populations to coarser sizes to raise
hit rates is the **population-quantization knob**, wired through
``plan_spgemm(pop_quant=True)`` (``core.plan``): bucket populations (and
distributed ``rows_pb``) are pow2-padded, degree bounds pow2-rounded
(:data:`POW2_DEG_ALIGN`) and capacities pow2-rounded, so *same-family,
different-seed* matrices land on the same plan key at ≤2× row padding;
``BinningPlan.signatures()`` exposes the static part so callers (and tests)
can check signature-level overlap.
"""
from __future__ import annotations

import dataclasses

import numpy as np

DEFAULT_LANE_BUDGET = 1 << 17   # lanes per kernel block: BS·F2 ≤ budget
DEFAULT_MAX_BLOCK_ROWS = 256
DEFAULT_MIN_ROWS = 32           # coalesce buckets smaller than this

# Accumulator routes (DESIGN.md §5).  ESC = expand/sort/compress: the bitonic
# sort + adjacent-unique (symbolic) / segmented run-sum (numeric) backend.
# SPA = accumulator backend: bitmask-popcount distinct count (symbolic) and a
# dense column-tiled scatter accumulator (numeric).
ROUTE_ESC = "esc"
ROUTE_SPA = "spa"
ROUTES = (ROUTE_ESC, ROUTE_SPA)

SPA_MIN_TILE = 128              # one VPU lane row — never tile finer

# ``round_deg`` align sentinel: any align ≥ the degree collapses the rule to
# pure pow2 rounding (``d <= align`` branch) — the degree-bound half of the
# population-quantization knob (``plan_spgemm(pop_quant=True)``).
POW2_DEG_ALIGN = 1 << 60
DEFAULT_SPA_MIN_BLOCK_ROWS = 64  # auto-route gate: dense tiles need tall
                                 # blocks to amortize the per-tile touch


def ceil_pow2(n: int) -> int:
    """Smallest power of two ≥ max(1, n)."""
    return 1 << max(0, (int(n) - 1).bit_length())


def floor_pow2(n: int) -> int:
    """Largest power of two ≤ max(1, n)."""
    return 1 << (max(1, int(n)).bit_length() - 1)


def round_deg(d: int, align: int = 1) -> int:
    """Degree bound rounding.  ``align=1`` keeps the exact bucket maximum —
    binned lanes are then ≤ global lanes for every row, by construction.
    Larger ``align`` quantizes (pow2 below ``align``, then multiples of it),
    trading ≤ ~1/align buffer inflation for a smaller signature set that
    jit-cache-shares across differently-shaped matrices."""
    d = max(1, int(d))
    if align <= 1:
        return d
    if d <= align:
        return ceil_pow2(d)
    return ((d + align - 1) // align) * align


@dataclasses.dataclass(frozen=True)
class RowBucket:
    """One degree bucket: static shapes + the row ids that run under them."""

    rows: np.ndarray      # int32 (n,) output-row ids, ascending
    deg_a: int            # bound on A-row degree within the bucket
    deg_b: int            # bound on referenced-B-row degree
    block_rows: int       # grid block height for this bucket's kernels
    route: str = ROUTE_ESC  # accumulator backend: "esc" (sort) or "spa"
    tile_n: int = 0       # SPA dense-accumulator column tile (0 on esc)
    n_tiles: int = 0      # SPA column-tile count (0 on esc)
    span: int = 0         # bound on per-row product-column extent (0 = ncols)

    @property
    def n_rows(self) -> int:
        return int(self.rows.size)

    @property
    def width(self) -> int:
        """Gather-buffer lanes per row (before kernel pow2 rounding)."""
        return self.deg_a * self.deg_b

    @property
    def lanes(self) -> int:
        """Total expanded-buffer lanes this bucket processes."""
        return self.n_rows * self.width

    @property
    def signature(self) -> tuple[int, int, int, str, int, int]:
        """The static shape tuple device executors specialize on."""
        return (self.deg_a, self.deg_b, self.block_rows, self.route,
                self.tile_n, self.span)


@dataclasses.dataclass(frozen=True)
class BinningPlan:
    """Partition of all output rows into degree buckets."""

    buckets: tuple[RowBucket, ...]
    nrows: int
    global_deg_a: int         # the global-pad bounds the plan replaces
    global_deg_b: int
    row_bucket: np.ndarray    # int32 (nrows,) row → bucket index

    @property
    def lanes(self) -> int:
        """Expanded-buffer lanes processed by the binned pipeline."""
        return sum(b.lanes for b in self.buckets)

    @property
    def global_lanes(self) -> int:
        """Lanes the global-pad pipeline processes for the same rows."""
        return self.nrows * max(1, self.global_deg_a * self.global_deg_b)

    @property
    def lane_reduction(self) -> float:
        """How many× fewer lanes the binned pipeline touches (≥ 1 good)."""
        return self.global_lanes / max(1, self.lanes)

    def signatures(self) -> tuple[tuple[int, int, int, str, int, int], ...]:
        """Sorted unique bucket signatures — the compile-cache key set."""
        return tuple(sorted({b.signature for b in self.buckets}))

    def route_rows(self) -> dict:
        """Rows per accumulator route — the planner's routing decision."""
        out = {r: 0 for r in ROUTES}
        for b in self.buckets:
            out[b.route] += b.n_rows
        return out

    def inverse_perm(self) -> np.ndarray:
        """Permutation restoring row-id order from bucket-concatenation order.

        Buckets partition the rows, so ``concat(per-bucket results)[perm]``
        assembles a full per-row array without per-bucket scatter copies —
        the shared assembly idiom of the binned executors."""
        return np.argsort(
            np.concatenate([b.rows for b in self.buckets])
            if self.buckets else np.zeros(0, np.int32), kind="stable")

    def subset(self, rows: np.ndarray) -> list[np.ndarray]:
        """Bucket an arbitrary row list (e.g. the sampled rows) under this
        plan — entry ``i`` holds the rows of ``rows`` that live in bucket
        ``i`` (duplicates preserved: sampling is with replacement)."""
        rows = np.asarray(rows, dtype=np.int64)
        which = self.row_bucket[rows]
        return [np.ascontiguousarray(rows[which == i].astype(np.int32))
                for i in range(len(self.buckets))]

    def stats(self) -> dict:
        return dict(
            num_buckets=len(self.buckets),
            lanes_binned=self.lanes,
            lanes_global=self.global_lanes,
            lane_reduction=round(self.lane_reduction, 3),
            signatures=[list(s) for s in self.signatures()],
            bucket_rows=[b.n_rows for b in self.buckets],
            bucket_widths=[b.width for b in self.buckets],
            bucket_routes=[b.route for b in self.buckets],
            route_rows=self.route_rows(),
        )


def _pick_block_rows(width: int, lane_budget: int, max_block_rows: int) -> int:
    """Largest pow2 block height with block·F2 lanes under the VMEM budget."""
    f2 = ceil_pow2(width)
    fit = max(1, lane_budget // f2)
    blk = 1 << (fit.bit_length() - 1)          # floor to pow2
    return int(max(1, min(max_block_rows, blk)))


# --------------------------------------------------------------------------- #
# Accumulator routing (DESIGN.md §5): sort/ESC vs bitmask/dense-SPA per bucket.
# --------------------------------------------------------------------------- #
def row_spans(a_rpt: np.ndarray, a_col: np.ndarray, b_rpt: np.ndarray,
              b_col: np.ndarray) -> np.ndarray:
    """Per-output-row product-column extent ``hi - lo + 1`` (≥ 1).

    The SPA kernels address their bitmask words / dense tile relative to
    each row's minimum product column, so their static lane count is the
    bucket's worst *extent*, not ``ncols_b`` — for banded/FEM structure the
    extent is the band width, orders of magnitude below the column count.
    Rows with no products get extent 1.
    """
    a_rpt = np.asarray(a_rpt, dtype=np.int64)
    a_col = np.asarray(a_col, dtype=np.int64)
    b_rpt = np.asarray(b_rpt, dtype=np.int64)
    b_col = np.asarray(b_col, dtype=np.int64)
    m = a_rpt.size - 1
    mb = b_rpt.size - 1
    big = np.int64(np.iinfo(np.int32).max)
    b_lo = np.full(mb, big)
    b_hi = np.full(mb, -1, dtype=np.int64)
    ne_b = np.diff(b_rpt) > 0
    if b_rpt[-1]:
        starts = b_rpt[:-1][ne_b]
        b_lo[ne_b] = np.minimum.reduceat(b_col[: b_rpt[-1]], starts)
        b_hi[ne_b] = np.maximum.reduceat(b_col[: b_rpt[-1]], starts)
    lo = np.full(m, big)
    hi = np.full(m, -1, dtype=np.int64)
    ne_a = np.diff(a_rpt) > 0
    if a_rpt[-1]:
        ks = np.clip(a_col[: a_rpt[-1]], 0, mb - 1)
        starts = a_rpt[:-1][ne_a]
        lo[ne_a] = np.minimum.reduceat(b_lo[ks], starts)
        hi[ne_a] = np.maximum.reduceat(b_hi[ks], starts)
    return np.maximum(1, hi - lo + 1)


def spa_tile(span: int, lane_budget: int) -> tuple[int, int]:
    """SPA dense-accumulator column tiling: ``(tile_n, n_tiles)``.

    One tile covering the pow2-padded column *extent* when it fits the VMEM
    lane budget (with at least a minimal block height), else the largest
    pow2 tile that does; ``n_tiles`` tiles then cover ``next_pow2(span)``
    exactly.
    """
    n_pad = ceil_pow2(max(1, int(span)))
    cap = max(SPA_MIN_TILE, floor_pow2(max(1, lane_budget // 8)))
    tile = min(max(n_pad, SPA_MIN_TILE), cap)
    return tile, -(-n_pad // tile)


def route_costs(deg_a: int, deg_b: int, ncols_b: int, span: int | None = None,
                lane_budget: int = DEFAULT_LANE_BUDGET) -> dict:
    """Per-row lane-op cost model deciding a bucket's accumulator route.

    ESC pays the bitonic network over the pow2-rounded gather width ``F2``
    in both phases — ``~3·w·log2²(F2)`` lane-ops (symbolic sort + the
    pricier key/value sort of the numeric phase).  SPA pays the
    broadcast-compare accumulation against its column extent: ``w`` products
    each checked against ``extent/32`` bitmask words (symbolic) and
    ``extent`` dense tile lanes (numeric), plus the tile touch itself.
    Constant factors are coarse — the regimes the router must separate
    (banded/FEM extent ≪ log²w·32 vs ER/power-law extent ≈ ncols) differ by
    well over 2×.
    """
    w = max(1, int(deg_a) * int(deg_b))
    f2 = ceil_pow2(w)
    lg = max(1, f2.bit_length() - 1)
    span = int(ncols_b if span is None else min(span, ncols_b))
    tile_n, n_tiles = spa_tile(span, lane_budget)
    cols = n_tiles * tile_n
    spa = w * (cols + -(-cols // 32)) + cols
    return dict(esc=3 * w * lg * lg, spa=spa, tile_n=tile_n, n_tiles=n_tiles,
                span=span)


def choose_route(deg_a: int, deg_b: int, ncols_b: int, span: int | None = None,
                 *, lane_budget: int = DEFAULT_LANE_BUDGET,
                 spa_min_block_rows: int = DEFAULT_SPA_MIN_BLOCK_ROWS
                 ) -> tuple[str, int, int]:
    """``(route, tile_n, n_tiles)`` for one bucket's static bounds.

    SPA is picked iff it wins the :func:`route_costs` comparison AND the
    dense tile leaves at least ``spa_min_block_rows`` rows per kernel block
    under the VMEM lane budget — a wide accumulator shared by only a handful
    of rows spends its time touching the tile, not accumulating, so such
    buckets stay on the sort path (this is also what keeps wide power-law
    column spaces on ESC).
    """
    c = route_costs(deg_a, deg_b, ncols_b, span, lane_budget)
    spa_block = floor_pow2(max(1, lane_budget // c["tile_n"]))
    if spa_block < spa_min_block_rows or c["spa"] >= c["esc"]:
        return ROUTE_ESC, 0, 0
    return ROUTE_SPA, c["tile_n"], c["n_tiles"]


def row_widths(a_rpt: np.ndarray, a_col: np.ndarray,
               rownnz_b: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-output-row (deg_a, dbmax, width) from host CSR index arrays."""
    a_rpt = np.asarray(a_rpt, dtype=np.int64)
    a_col = np.asarray(a_col, dtype=np.int64)
    rownnz_b = np.asarray(rownnz_b, dtype=np.int64)
    m = a_rpt.size - 1
    nnz = int(a_rpt[-1])
    deg_a = np.diff(a_rpt)
    # max referenced-B degree per row: maximum.reduceat over the CSR slices
    per_nnz = rownnz_b[np.clip(a_col[:nnz], 0, rownnz_b.size - 1)]
    dbmax = np.zeros(m, dtype=np.int64)
    nonempty = deg_a > 0
    if nnz:
        starts = a_rpt[:-1][nonempty]
        dbmax[nonempty] = np.maximum.reduceat(per_nnz, starts)
    width = np.maximum(1, deg_a * dbmax)
    return deg_a, dbmax, width


def panel_row_tables(a_rpt: np.ndarray, a_col: np.ndarray,
                     panel_rpts) -> tuple[np.ndarray, np.ndarray]:
    """Per-panel per-output-row degree tables for column-partitioned B.

    ``panel_rpts`` is one CSR row-pointer array per column panel of B (the
    panel slices share B's row ids; only the entries are split).  Returns
    ``(dbmax, flopr)``, each ``(n_panels, m)``:

      * ``dbmax[p, i]`` — the largest *panel-p* degree among the B rows that
        output row ``i`` references: the per-panel gather-buffer bound that
        replaces the full-row ``dbmax`` of :func:`row_widths`;
      * ``flopr[p, i]`` — row ``i``'s FLOP restricted to panel ``p``
        (Algorithm 1 per panel); panels partition B's entries, so
        ``flopr.sum(axis=0)`` equals the full-row FLOP exactly.

    This is THE symbolic-phase degree table of the panel pipeline — computed
    once from the panel slices and reused by capacity planning AND the
    numeric gather (the (bucket × panel) dedup, DESIGN.md §8).
    """
    a_rpt = np.asarray(a_rpt, dtype=np.int64)
    a_col = np.asarray(a_col, dtype=np.int64)
    m = a_rpt.size - 1
    nnz = int(a_rpt[-1])
    n_panels = len(panel_rpts)
    dbmax = np.zeros((n_panels, m), dtype=np.int64)
    flopr = np.zeros((n_panels, m), dtype=np.int64)
    nonempty = np.diff(a_rpt) > 0
    starts = a_rpt[:-1][nonempty]
    for p, prpt in enumerate(panel_rpts):
        rownnz_p = np.diff(np.asarray(prpt, dtype=np.int64))
        if not nnz:
            continue
        per = rownnz_p[np.clip(a_col[:nnz], 0, rownnz_p.size - 1)]
        dbmax[p, nonempty] = np.maximum.reduceat(per, starts)
        flopr[p, nonempty] = np.add.reduceat(per, starts)
    return dbmax, flopr


def build_plan(a, b, *, lane_budget: int = DEFAULT_LANE_BUDGET,
               max_block_rows: int = DEFAULT_MAX_BLOCK_ROWS,
               min_rows: int = DEFAULT_MIN_ROWS,
               deg_align: int = 1, route: str = "auto",
               spa_min_block_rows: int = DEFAULT_SPA_MIN_BLOCK_ROWS
               ) -> BinningPlan:
    """Plan the binned execution of ``C = A·B``.

    ``a``/``b`` may be host ``CSR`` or device ``CSRDevice`` — only the int
    index arrays are read (pulled to host; planning is a launch-time step).

    ``route`` selects the accumulator backend per bucket: ``"auto"`` applies
    the :func:`choose_route` cost model; ``"esc"``/``"spa"`` force every
    bucket onto one backend (forced SPA falls back to column tiling instead
    of being rejected by the VMEM gate — outputs are route-invariant either
    way, see DESIGN.md §5).
    """
    if route not in ("auto",) + ROUTES:
        from .errors import PlanMismatchError
        raise PlanMismatchError(f"unknown route {route!r}")
    a_rpt = np.asarray(a.rpt)
    a_col = np.asarray(a.col)
    b_rpt = np.asarray(b.rpt)
    rownnz_b = np.diff(b_rpt.astype(np.int64))
    deg_a, dbmax, width = row_widths(a_rpt, a_col, rownnz_b)
    m = deg_a.size

    # pow2 bucket key per row → ascending width groups (≤ ~log2(max_width))
    key = np.ceil(np.log2(np.maximum(width, 1))).astype(np.int64)
    order = np.argsort(key, kind="stable")
    sorted_key = key[order]
    _, starts_u, counts = np.unique(sorted_key, return_index=True,
                                    return_counts=True)
    groups = [order[s0:s0 + c] for s0, c in zip(starts_u, counts)]

    def bounds(ids):
        da = round_deg(int(deg_a[ids].max()), deg_align) if ids.size else 1
        db = round_deg(int(dbmax[ids].max()), deg_align) if ids.size else 1
        return da, db

    # Coalesce, ascending, and ONLY ever upward: a small group rides along
    # with the next larger-width bucket (a few rows pay a wider buffer).
    # Never merge downward — pulling one hub bucket into a big small-width
    # group would re-inflate every row to hub width, which is exactly the
    # pathology binning exists to remove.  Adjacent groups whose degree
    # bounds coincide merge for free (same compiled program either way).
    merged: list[np.ndarray] = []
    carry: np.ndarray | None = None
    for ids in groups:
        if carry is not None:
            ids = np.concatenate([carry, ids])
            carry = None
        if merged and bounds(np.concatenate([merged[-1], ids])) == bounds(merged[-1]):
            merged[-1] = np.concatenate([merged[-1], ids])
        elif ids.size < min_rows:
            carry = ids
        else:
            merged.append(ids)
    if carry is not None:
        if merged and bounds(np.concatenate([merged[-1], carry])) == bounds(merged[-1]):
            merged[-1] = np.concatenate([merged[-1], carry])
        else:
            merged.append(carry)        # trailing hub bucket stays isolated

    ncols_b = int(b.shape[1])
    # forced-ESC plans never read extents — skip the O(nnz) host pass
    spans = (row_spans(a_rpt, a_col, b_rpt, np.asarray(b.col))
             if route != ROUTE_ESC else None)
    buckets = []
    row_bucket = np.zeros(m, dtype=np.int32)
    for i, ids in enumerate(merged):
        ids = np.sort(ids).astype(np.int32)
        da, db = bounds(ids)
        # pow2-rounded extent bound: stable across same-family matrices, so
        # span does not fragment the signature (compile-cache) set
        span = min(ceil_pow2(int(spans[ids].max()))
                   if spans is not None and ids.size else 1,
                   ceil_pow2(ncols_b))
        blk = _pick_block_rows(da * db, lane_budget, max_block_rows)
        if route == ROUTE_ESC:
            rt, tile, ntiles = ROUTE_ESC, 0, 0
        elif route == ROUTE_SPA:
            rt = ROUTE_SPA
            tile, ntiles = spa_tile(span, lane_budget)
        else:
            rt, tile, ntiles = choose_route(
                da, db, ncols_b, span, lane_budget=lane_budget,
                spa_min_block_rows=spa_min_block_rows)
        if rt == ROUTE_SPA:
            # the block must also hold the dense column tile under the budget
            blk = int(max(1, min(blk, floor_pow2(
                max(1, lane_budget // tile)))))
        else:
            span = 0                 # ESC kernels never specialize on extent
        buckets.append(RowBucket(rows=ids, deg_a=da, deg_b=db, block_rows=blk,
                                 route=rt, tile_n=tile, n_tiles=ntiles,
                                 span=span))
        row_bucket[ids] = i

    gda = int(deg_a.max()) if m else 1
    gdb = int(rownnz_b.max()) if rownnz_b.size else 1
    return BinningPlan(buckets=tuple(buckets), nrows=m,
                       global_deg_a=max(1, gda), global_deg_b=max(1, gdb),
                       row_bucket=row_bucket)
