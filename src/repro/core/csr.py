"""Device-side (JAX) CSR with static shapes.

XLA requires static shapes, so the device CSR is *capacity-padded*: ``col`` /
``val`` have length ``cap >= nnz``; entries past ``nnz`` are padding (col
sentinel, val 0).  ``nnz`` itself stays a traced scalar so one compiled
program serves any matrix that fits the capacity — exactly the regime the
paper's predictor exists for (size the capacity before you compute).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse.formats import CSR

# Sentinel for padded column slots: larger than any real column index so that
# sorted buffers push padding to the tail and adjacent-unique never counts it.
COL_SENTINEL = np.int32(np.iinfo(np.int32).max)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CSRDevice:
    """Padded CSR on device.  ``shape``/capacity are static (aux) data."""

    rpt: jax.Array  # int32 (M+1,)
    col: jax.Array  # int32 (cap,), padded with COL_SENTINEL
    val: jax.Array  # float32 (cap,), padded with 0
    shape: tuple[int, int] = dataclasses.field(metadata=dict(static=True))

    @property
    def nrows(self) -> int:
        return self.shape[0]

    @property
    def ncols(self) -> int:
        return self.shape[1]

    @property
    def capacity(self) -> int:
        return self.col.shape[0]

    @property
    def nnz(self) -> jax.Array:
        return self.rpt[-1]

    @property
    def row_nnz(self) -> jax.Array:
        return jnp.diff(self.rpt)


def expand_products(a: "CSRDevice", b: "CSRDevice", rows: jax.Array,
                    max_deg_a: int, max_deg_b: int, *,
                    rownnz_b: jax.Array | None = None,
                    with_values: bool = False):
    """Expand the intermediate-product columns of ``rows`` of ``C = A·B`` into
    a static ``(S, max_deg_a·max_deg_b)`` buffer — THE shared gather of both
    phases (symbolic predictor and numeric SpGEMM) and of every accumulator
    route (sort/ESC, bitmask, dense-SPA).

    Returns ``(cols, vals, valid)``:

      * ``cols``  — int32, padded with :data:`COL_SENTINEL`;
      * ``vals``  — float32 value products (``a_ik·b_kj``), 0 on padding —
        ``None`` unless ``with_values`` (the symbolic phase never reads them);
      * ``valid`` — bool mask of real (non-padding) product slots.

    ``rownnz_b`` (``= jnp.diff(b.rpt)``) may be passed in so bucket-iterated
    callers hoist the diff out of their per-bucket calls.
    """
    s = rows.shape[0]
    deg_a = (a.rpt[rows + 1] - a.rpt[rows]).astype(jnp.int32)             # (S,)
    ia = jnp.arange(max_deg_a, dtype=jnp.int32)
    idx_a = jnp.clip(a.rpt[rows][:, None] + ia[None, :], 0, a.capacity - 1)
    valid_a = ia[None, :] < deg_a[:, None]
    ks = jnp.where(valid_a, a.col[idx_a], 0)                              # (S, DA)

    if rownnz_b is None:
        rownnz_b = jnp.diff(b.rpt)
    deg_b = jnp.where(valid_a, rownnz_b[ks], 0)
    ib = jnp.arange(max_deg_b, dtype=jnp.int32)
    idx_b = jnp.clip(b.rpt[ks][:, :, None] + ib[None, None, :], 0, b.capacity - 1)
    valid = valid_a[:, :, None] & (ib[None, None, :] < deg_b[:, :, None])
    cols = jnp.where(valid, b.col[idx_b], COL_SENTINEL)
    f = max_deg_a * max_deg_b
    vals = None
    if with_values:
        av = jnp.where(valid_a, a.val[idx_a], 0.0)
        vals = jnp.where(valid, av[:, :, None] * b.val[idx_b], 0.0).reshape(s, f)
    return cols.reshape(s, f), vals, valid.reshape(s, f)


def pad_row_ids(rows: jax.Array, multiple: int) -> jax.Array:
    """Pad a row-id list to a multiple of ``multiple`` by repeating the LAST
    listed row (padded outputs are sliced off by the caller).

    Shared by every blocked row-list executor.  Repeating the last row — not
    row 0 — matters under degree binning: the list is then a bucket, and row
    0 of the matrix may exceed the bucket's degree envelope while a repeated
    member row cannot.
    """
    r = rows.shape[0]
    pad_r = (-(-r // multiple)) * multiple
    rows = rows.astype(jnp.int32)
    if pad_r == r:
        return rows
    return jnp.concatenate([rows, jnp.broadcast_to(rows[-1:], (pad_r - r,))])


def to_device(host: CSR, capacity: int | None = None) -> CSRDevice:
    cap = int(capacity if capacity is not None else host.nnz)
    if cap < host.nnz:
        from .errors import PlanMismatchError
        raise PlanMismatchError(
            f"device capacity {cap} is smaller than the operand's nnz "
            f"{host.nnz}", observed=int(host.nnz), planned=cap)
    col = np.full(cap, COL_SENTINEL, dtype=np.int32)
    val = np.zeros(cap, dtype=np.float32)
    col[: host.nnz] = host.col
    val[: host.nnz] = host.val
    return CSRDevice(
        rpt=jnp.asarray(host.rpt, dtype=jnp.int32),
        col=jnp.asarray(col),
        val=jnp.asarray(val),
        shape=host.shape,
    )


def to_host(dev: CSRDevice) -> CSR:
    rpt = np.asarray(dev.rpt, dtype=np.int64)
    nnz = int(rpt[-1])
    return CSR(rpt=rpt, col=np.asarray(dev.col[:nnz], dtype=np.int32),
               val=np.asarray(dev.val[:nnz], dtype=np.float32), shape=dev.shape)
