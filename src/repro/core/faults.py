"""Deterministic, seedable fault injection for the plan/execute pipeline
(DESIGN.md §9).

The containment contract — under any injected fault, ``execute()`` /
``reassemble()`` either produce a result bitwise-equal to the dense oracle
or raise the matching typed :mod:`repro.core.errors` subclass, never a
silently corrupted matrix — is only provable if faults can be injected on
demand.  This module provides the hooks, all plumbed through plan-time /
execute-time host code (never inside traced executors, so the no-fault path
costs nothing and compiled programs stay fault-free):

    with faults.inject(capacity_scale=0.25):
        plan = plan_spgemm(a, b, retry_policy=RetryPolicy())   # starved caps

Fault classes (one keyword each, composable):

* ``capacity_scale`` — scale every predicted output capacity down at
  allocation time (``predictor.AllocationPlan.from_prediction``), modeling
  a predictor that under-shoots uniformly.
* ``sketch_scale`` — corrupt the sampled sketch after prediction: the
  per-row structure is scaled by ``sketch_scale`` with seeded multiplicative
  jitter, the compression ratio inflated to match — the paper's "sampled
  rows were unlucky" failure, end to end.
* ``gather_scale`` — starve the panel-gather entry capacities
  (``PanelGather.ecap`` / the single-device per-panel operand caps) below
  the real payload.
* ``fail_executor`` / ``on_call`` — raise :class:`InjectedFault` on the
  Nth invocation of any executor whose dispatch info matches the given
  key/value filter (e.g. ``{"bucket": 2}`` or ``{"unit": "local"}``).

Everything is deterministic given ``seed``; nesting ``inject`` contexts
stacks (innermost wins per fault class).
"""
from __future__ import annotations

import contextlib
import dataclasses

import numpy as np


class InjectedFault(RuntimeError):
    """Raised by an armed ``fail_executor`` hook; the pipeline wraps it into
    :class:`repro.core.errors.ShardFailureError` naming the unit."""


@dataclasses.dataclass(eq=False)   # identity compare: the inject() unwind
class FaultState:                  # must never pop a LOOK-ALIKE sibling
    capacity_scale: float | None = None
    sketch_scale: float | None = None
    gather_scale: float | None = None
    fail_executor: dict | None = None
    on_call: int = 1
    seed: int = 0
    executor_calls: int = 0      # matching-dispatch counter (mutable)


_STACK: list[FaultState] = []


def _active(field: str) -> FaultState | None:
    """Innermost injected state that arms ``field`` (None = no fault)."""
    for st in reversed(_STACK):
        if getattr(st, field) is not None:
            return st
    return None


@contextlib.contextmanager
def inject(*, capacity_scale: float | None = None,
           sketch_scale: float | None = None,
           gather_scale: float | None = None,
           fail_executor: dict | None = None,
           on_call: int = 1, seed: int = 0):
    """Arm the selected fault classes for the dynamic extent of the block."""
    st = FaultState(capacity_scale=capacity_scale, sketch_scale=sketch_scale,
                    gather_scale=gather_scale, fail_executor=fail_executor,
                    on_call=int(on_call), seed=int(seed))
    _STACK.append(st)
    try:
        yield st
    finally:
        # Re-entrancy guard: unwind by IDENTITY, tolerating double exit and
        # a stack perturbed by the guarded block raising — the hooks are
        # restored no matter how the block leaves, so a service worker loop
        # can never leak an armed fault from one request into the next.
        for i in range(len(_STACK) - 1, -1, -1):
            if _STACK[i] is st:
                del _STACK[i]
                break


def armed() -> bool:
    """True while any ``inject`` context is active (observability hook —
    the serving layer stamps it into per-request stats)."""
    return bool(_STACK)


# --------------------------------------------------------------------------- #
# Hooks (called from plan/predictor host code; no-ops when nothing is armed)
# --------------------------------------------------------------------------- #
def scale_capacity(cap: int) -> int:
    st = _active("capacity_scale")
    if st is None:
        return cap
    return max(1, int(cap * st.capacity_scale))


def scale_gather_cap(cap: int) -> int:
    st = _active("gather_scale")
    if st is None:
        return cap
    return max(1, int(cap * st.gather_scale))


def corrupt_sketch(structure: np.ndarray, predicted_nnz: float,
                   cr: float) -> tuple[np.ndarray, float, float]:
    """Scale the predicted per-row structure by ``sketch_scale`` with seeded
    per-row jitter, keeping (structure, nnz, cr) self-consistent."""
    st = _active("sketch_scale")
    if st is None:
        return structure, predicted_nnz, cr
    rng = np.random.default_rng(st.seed)
    jitter = rng.uniform(0.5, 1.0, size=structure.shape)
    corrupted = structure * st.sketch_scale * jitter
    return corrupted, float(corrupted.sum()), cr / max(st.sketch_scale, 1e-9)


def check_executor(info: dict) -> None:
    """Dispatch-time hook: raise :class:`InjectedFault` when this dispatch
    matches the armed filter and the matching-call counter hits ``on_call``."""
    st = _active("fail_executor")
    if st is None:
        return
    if all(info.get(k) == v for k, v in st.fail_executor.items()):
        st.executor_calls += 1
        if st.executor_calls == st.on_call:
            raise InjectedFault(
                f"injected executor fault (call {st.on_call}) at {info}")
