"""Numpy oracle implementations of every output-structure method in the paper.

These are the ground-truth references for the JAX/Pallas implementations and
the engines for the 625-case accuracy reproduction (host-side, vectorized).

Methods (paper Section I / IV):
  * ``flop_per_row``        — Algorithm 1: the upper-bound method.
  * ``exact_structure``     — the precise method (symbolic phase).
  * ``reference_predict``   — reference design of the existing sampling method:
                              row-wise sampling + exact sampled count,
                              Z1* = z*/p                      (paper eq. 2).
  * ``proposed_predict``    — THE PAPER'S METHOD (Algorithm 2): sampled
                              compression ratio r* = f*/z*,
                              Z2* = F/r*                      (paper eq. 4).
  * ``minhash_predict``     — the original existing estimator (Bar-Yossef /
                              Amossen k-min hash distinct-count) on the same
                              sampled product stream.

All functions operate on host ``CSR`` (see ``repro.sparse.formats``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.sparse.formats import CSR

# Paper, Algorithm 2 line 1: sample_num = min(0.003 * M, 300).
SAMPLE_FRACTION = 0.003
SAMPLE_CAP = 300


# --------------------------------------------------------------------------- #
# Algorithm 1 — FLOP per output row (upper-bound method)
# --------------------------------------------------------------------------- #
def flop_per_row(a: CSR, b: CSR) -> tuple[np.ndarray, int]:
    """floprC[i] = sum_{k in cols(A_i*)} nnz(B_k*);  total_flop = sum_i floprC[i].

    Vectorized equivalent of the paper's Algorithm 1: only touches A.rpt,
    A.col and B.rpt.
    """
    assert a.ncols == b.nrows, (a.shape, b.shape)
    rownnz_b = b.row_nnz  # B.rpt[k+1] - B.rpt[k]
    contrib = rownnz_b[a.col]  # one entry per nonzero of A
    row_of_nnz = np.repeat(np.arange(a.nrows, dtype=np.int64), a.row_nnz)
    floprc = np.zeros(a.nrows, dtype=np.int64)
    np.add.at(floprc, row_of_nnz, contrib)
    return floprc, int(floprc.sum())


# --------------------------------------------------------------------------- #
# Intermediate-product stream expansion (row-wise dataflow, Section II-C)
# --------------------------------------------------------------------------- #
def _slice_concat(starts: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """Vectorized concatenation of index ranges [starts_i, starts_i+counts_i)."""
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    offs = np.cumsum(counts) - counts
    out = np.arange(total, dtype=np.int64)
    out -= np.repeat(offs, counts)
    out += np.repeat(starts.astype(np.int64), counts)
    return out


def expand_products(a: CSR, b: CSR, rows: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """All intermediate products C_{i*} += A_ik * B_k* for the given A rows.

    Returns ``(owner, col)`` where ``owner`` indexes into ``rows`` (so a row
    sampled twice is expanded twice, matching Algorithm 2's with-replacement
    sampling) and ``col`` is the output column of each product.
    """
    rows = np.asarray(rows, dtype=np.int64)
    deg_a = (a.rpt[rows + 1] - a.rpt[rows]).astype(np.int64)
    idx_a = _slice_concat(a.rpt[rows], deg_a)
    ks = a.col[idx_a].astype(np.int64)
    owner_a = np.repeat(np.arange(rows.size, dtype=np.int64), deg_a)
    deg_b = (b.rpt[ks + 1] - b.rpt[ks]).astype(np.int64)
    idx_b = _slice_concat(b.rpt[ks], deg_b)
    col = b.col[idx_b].astype(np.int64)
    owner = np.repeat(owner_a, deg_b)
    return owner, col


# --------------------------------------------------------------------------- #
# Precise method (symbolic phase) — chunked to bound peak memory
# --------------------------------------------------------------------------- #
def exact_structure(a: CSR, b: CSR, chunk_flop: int = 1 << 23) -> tuple[np.ndarray, int]:
    """Exact nnz per output row of C = A·B (structure only), and total NNZ(C)."""
    floprc, _ = flop_per_row(a, b)
    m, n = a.nrows, b.ncols
    nnzr = np.zeros(m, dtype=np.int64)
    cum = np.concatenate([[0], np.cumsum(floprc)])
    start = 0
    while start < m:
        end = int(np.searchsorted(cum, cum[start] + chunk_flop, side="right"))
        end = max(start + 1, min(end, m))
        owner, col = expand_products(a, b, np.arange(start, end))
        keys = owner * np.int64(n) + col
        uniq = np.unique(keys)
        cnt = np.bincount((uniq // n).astype(np.int64), minlength=end - start)
        nnzr[start:end] = cnt
        start = end
    return nnzr, int(nnzr.sum())


def exact_sampled_nnz(a: CSR, b: CSR, rows: np.ndarray) -> int:
    """z* — exact NNZ of the sampled result rows (Algorithm 2 lines 7-31)."""
    owner, col = expand_products(a, b, rows)
    keys = owner * np.int64(b.ncols) + col
    return int(np.unique(keys).size)


# --------------------------------------------------------------------------- #
# Sampling (Algorithm 2 lines 1-3, with replacement as in the paper)
# --------------------------------------------------------------------------- #
def sample_rows(m: int, seed: int, fraction: float = SAMPLE_FRACTION, cap: int = SAMPLE_CAP) -> np.ndarray:
    sample_num = max(1, min(int(fraction * m), cap))
    rng = np.random.default_rng(seed)
    rand = rng.random(sample_num)  # the paper's `rand` array
    return (m * rand).astype(np.int64).clip(0, m - 1)


# --------------------------------------------------------------------------- #
# Prediction results container
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class Prediction:
    nnz_total: float          # predicted NNZ(C)  (Z1* or Z2*)
    structure: np.ndarray     # predicted nnz per output row
    compression_ratio: float  # predicted CR of the task
    sampled_flop: int         # f*
    sampled_nnz: int          # z*
    sample_num: int
    total_flop: int           # F (always exact, Algorithm 1)


def reference_predict(a: CSR, b: CSR, seed: int = 0,
                      rows: Optional[np.ndarray] = None) -> Prediction:
    """Reference design (paper eq. 2): Z1* = z*/p, structure = flopr / (F/Z1*)."""
    floprc, total_flop = flop_per_row(a, b)
    if rows is None:
        rows = sample_rows(a.nrows, seed)
    z_star = exact_sampled_nnz(a, b, rows)
    f_star = int(floprc[rows].sum())
    p = rows.size / a.nrows
    z1 = z_star / p
    cr = total_flop / max(z1, 1.0)
    return Prediction(z1, floprc / cr, cr, f_star, z_star, rows.size, total_flop)


def proposed_predict(a: CSR, b: CSR, seed: int = 0,
                     rows: Optional[np.ndarray] = None) -> Prediction:
    """THE PAPER'S METHOD (eq. 4 / Algorithm 2 line 32).

    r* = f*/z*;  Z2* = F / r* = total_flop / sample_flop * sample_nnz;
    predicted structure = floprC / r*.
    """
    floprc, total_flop = flop_per_row(a, b)
    if rows is None:
        rows = sample_rows(a.nrows, seed)
    z_star = exact_sampled_nnz(a, b, rows)
    f_star = int(floprc[rows].sum())
    r_star = f_star / max(z_star, 1)
    z2 = total_flop / r_star
    return Prediction(z2, floprc / r_star, r_star, f_star, z_star, rows.size, total_flop)


# --------------------------------------------------------------------------- #
# k-min hash estimator (Bar-Yossef / Amossen / Pham) — the original existing
# method's counting scheme, vectorized.
# --------------------------------------------------------------------------- #
_MERSENNE = (1 << 61) - 1


def _hash01(keys: np.ndarray, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed ^ 0x5EED)
    aa = int(rng.integers(1, _MERSENNE))
    bb = int(rng.integers(0, _MERSENNE))
    hv = (keys.astype(np.uint64) * np.uint64(aa) + np.uint64(bb)) % np.uint64(_MERSENNE)
    return hv.astype(np.float64) / float(_MERSENNE)


def minhash_predict(a: CSR, b: CSR, seed: int = 0, k: int = 64,
                    rows: Optional[np.ndarray] = None) -> Prediction:
    """Existing method's estimator on the sampled product stream.

    Applies h:[m,n]→[0,1] to every intermediate product of the sampled rows,
    keeps the k-th smallest *distinct* hashed value v, and predicts
    NNZ(C') = k/v (paper Section III), then NNZ(C) = NNZ(C')/p.
    """
    floprc, total_flop = flop_per_row(a, b)
    if rows is None:
        rows = sample_rows(a.nrows, seed)
    owner, col = expand_products(a, b, rows)
    keys = owner * np.int64(b.ncols) + col
    hv = np.unique(_hash01(keys, seed))  # distinct hashed values, sorted
    if hv.size <= k:  # fewer distinct than k → count is exact
        z_star = float(hv.size)
    else:
        v = hv[k - 1]
        z_star = k / v if v > 0 else float(hv.size)
    f_star = int(floprc[rows].sum())
    p = rows.size / a.nrows
    z_pred = z_star / p
    cr = total_flop / max(z_pred, 1.0)
    return Prediction(z_pred, floprc / cr, cr, f_star, int(z_star), rows.size, total_flop)


def stratified_predict(a: CSR, b: CSR, seed: int = 0, num_segments: int = 64,
                       per_segment: int = 8) -> Prediction:
    """BEYOND-PAPER: stratified sampled-CR for heterogeneous matrices.

    The paper's prediction divides flopr by ONE global CR*, so its structure
    estimate is proportional to flopr — it cannot distinguish regions whose
    per-row compression differs (and prediction-balanced partitions then
    coincide with FLOP-balanced ones).  Stratifying the sample — a few rows
    per contiguous row segment, one CR* per segment — keeps the paper's
    error-cancellation *within* each stratum while capturing CR variation
    *across* strata.  Cost: num_segments×per_segment sampled rows (512 at the
    defaults) vs min(0.003·M, 300); still ≪ the precise method.
    """
    floprc, total_flop = flop_per_row(a, b)
    bounds = np.linspace(0, a.nrows, num_segments + 1).astype(np.int64)
    structure = np.zeros(a.nrows, dtype=np.float64)
    rng = np.random.default_rng(seed)
    f_star_total = 0
    z_star_total = 0
    for s in range(num_segments):
        lo, hi = int(bounds[s]), int(bounds[s + 1])
        if hi <= lo:
            continue
        rows = lo + (rng.random(per_segment) * (hi - lo)).astype(np.int64)
        f_star = int(floprc[rows].sum())
        if f_star == 0:
            structure[lo:hi] = 0.0
            continue
        z_star = exact_sampled_nnz(a, b, rows)
        cr = f_star / max(z_star, 1)
        structure[lo:hi] = floprc[lo:hi] / cr
        f_star_total += f_star
        z_star_total += z_star
    total = float(structure.sum())
    cr_glob = total_flop / max(total, 1.0)
    return Prediction(total, structure, cr_glob, f_star_total, z_star_total,
                      num_segments * per_segment, total_flop)


def upper_bound_predict(a: CSR, b: CSR) -> Prediction:
    """Upper-bound method: the structure IS floprC (CR assumed 1)."""
    floprc, total_flop = flop_per_row(a, b)
    return Prediction(float(total_flop), floprc.astype(np.float64), 1.0,
                      total_flop, total_flop, 0, total_flop)


# --------------------------------------------------------------------------- #
# Numeric SpGEMM oracle (values), used by the numeric-kernel tests
# --------------------------------------------------------------------------- #
def spgemm(a: CSR, b: CSR, chunk_flop: int = 1 << 23) -> CSR:
    """Exact C = A·B via row-wise expansion + key-collapse (host oracle)."""
    floprc, _ = flop_per_row(a, b)
    m, n = a.nrows, b.ncols
    cum = np.concatenate([[0], np.cumsum(floprc)])
    rows_out, cols_out, vals_out = [], [], []
    start = 0
    while start < m:
        end = int(np.searchsorted(cum, cum[start] + chunk_flop, side="right"))
        end = max(start + 1, min(end, m))
        rows = np.arange(start, end)
        deg_a = (a.rpt[rows + 1] - a.rpt[rows]).astype(np.int64)
        idx_a = _slice_concat(a.rpt[rows], deg_a)
        ks = a.col[idx_a].astype(np.int64)
        av = a.val[idx_a]
        owner_a = np.repeat(np.arange(rows.size, dtype=np.int64), deg_a)
        deg_b = (b.rpt[ks + 1] - b.rpt[ks]).astype(np.int64)
        idx_b = _slice_concat(b.rpt[ks], deg_b)
        col = b.col[idx_b].astype(np.int64)
        prod = np.repeat(av, deg_b) * b.val[idx_b]
        owner = np.repeat(owner_a, deg_b)
        keys = owner * np.int64(n) + col
        uniq, inv = np.unique(keys, return_inverse=True)
        acc = np.zeros(uniq.size, dtype=np.float64)
        np.add.at(acc, inv, prod.astype(np.float64))
        rows_out.append((uniq // n) + start)
        cols_out.append(uniq % n)
        vals_out.append(acc.astype(np.float32))
        start = end
    return CSR.from_coo(np.concatenate(rows_out), np.concatenate(cols_out),
                        np.concatenate(vals_out), (m, n), dedup=False)
