"""The paper's sampled-compression-ratio predictor, on device (JAX).

This is the TPU-native adaptation of Algorithm 2 (see DESIGN.md §3): the
per-thread hash table with linear probing is replaced by a
*gather → sort → adjacent-unique* reduction with fully static shapes:

  for each of S sampled rows of A:
      gather ≤ DA column indices of A's row            (DA = max row degree A)
      for each, gather ≤ DB column indices of B's row   (DB = max row degree B)
      → (S, DA*DB) buffer, padding = COL_SENTINEL
      sort along the last axis; count strict ascents among valid entries
  z* = Σ distinct counts;  f* = Σ valid counts
  r* = f*/z*;  Z2* = F/r*;  nnzr*(C) = floprC / r*        (paper eq. 4)

The same buffers drive the reference design  Z1* = z*/p  (paper eq. 2).
``repro.kernels.spgemm_symbolic`` is the Pallas version of the inner loop;
this module is its jnp oracle and the public API.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .csr import CSRDevice, COL_SENTINEL, expand_products
from .flop import flop_per_row
from .binning import BinningPlan, ROUTE_SPA, ceil_pow2
from . import faults as faults_mod

SAMPLE_FRACTION = 0.003
SAMPLE_CAP = 300


class PredictionDev(NamedTuple):
    nnz_total: jax.Array        # predicted NNZ(C)
    structure: jax.Array        # predicted nnz per output row (float32, (M,))
    compression_ratio: jax.Array
    sampled_flop: jax.Array
    sampled_nnz: jax.Array
    total_flop: jax.Array


def static_sample_num(m: int, fraction: float = SAMPLE_FRACTION, cap: int = SAMPLE_CAP) -> int:
    """Paper Algorithm 2 line 1, resolved statically from the row count."""
    return max(1, min(int(fraction * m), cap))


def draw_sample_rows(key: jax.Array, m: int, sample_num: int) -> jax.Array:
    """rid = M * rand[r]  (with replacement, as in the paper)."""
    rand = jax.random.uniform(key, (sample_num,))
    return jnp.clip((m * rand).astype(jnp.int32), 0, m - 1)


def gather_sampled_products(a: CSRDevice, b: CSRDevice, rows: jax.Array,
                            max_deg_a: int, max_deg_b: int,
                            rownnz_b: jax.Array | None = None
                            ) -> tuple[jax.Array, jax.Array]:
    """Expand the sampled rows' intermediate-product columns into a static
    buffer (column-only view of :func:`repro.core.csr.expand_products`).

    Returns (cols (S, DA*DB) int32 with COL_SENTINEL padding, valid mask).
    """
    cols, _, valid = expand_products(a, b, rows, max_deg_a, max_deg_b,
                                     rownnz_b=rownnz_b, with_values=False)
    return cols, valid


def count_distinct_sorted(cols: jax.Array) -> jax.Array:
    """Sort rows and count distinct non-sentinel entries per row (ESC)."""
    srt = jnp.sort(cols, axis=-1)
    first = (srt[:, :1] != COL_SENTINEL).astype(jnp.int32)
    ascents = ((srt[:, 1:] != srt[:, :-1]) & (srt[:, 1:] != COL_SENTINEL)).astype(jnp.int32)
    return first[:, 0] + ascents.sum(axis=-1)


def count_distinct_dense(cols: jax.Array, ncols_b: int,
                         span: int = 0) -> jax.Array:
    """Distinct non-sentinel entries per row via the bitmask-popcount
    accumulator — the SPA route's jnp path.

    A distinct count is a property of the column *set*, so this equals
    :func:`count_distinct_sorted` exactly.  ``span`` (the planner's per-row
    column-extent bound, 0 → full space) sizes the bitmask words; the
    columns are addressed relative to each row's minimum, so banded/FEM
    structure touches ``span/32`` word lanes instead of ``ncols_b/32``.
    (Same algorithm as the Pallas kernel — ``kernels.accumulator`` — which
    is pure static-shape jnp and therefore runs outside ``pallas_call``
    too; an XLA scatter would also work here but is element-serial on CPU.)
    """
    from repro.kernels.accumulator import bitmask_distinct
    n = min(int(span), ncols_b) if span else ncols_b
    return bitmask_distinct(cols, -(-n // 32))


@functools.partial(jax.jit, static_argnames=("max_deg_a", "max_deg_b", "use_kernel"))
def proposed_predict(a: CSRDevice, b: CSRDevice, rows: jax.Array,
                     max_deg_a: int, max_deg_b: int, use_kernel: bool = False) -> PredictionDev:
    """THE PAPER'S METHOD (eq. 4) on device.  ``rows`` from draw_sample_rows."""
    floprc, total_flop = flop_per_row(a, b)
    if use_kernel:
        from repro.kernels import ops as kops
        z_star, f_star = kops.sampled_symbolic(a, b, rows, max_deg_a, max_deg_b)
    else:
        cols, valid = gather_sampled_products(a, b, rows, max_deg_a, max_deg_b)
        z_star = count_distinct_sorted(cols).sum()
        f_star = valid.sum()
    r_star = f_star.astype(jnp.float32) / jnp.maximum(z_star, 1).astype(jnp.float32)
    z2 = total_flop.astype(jnp.float32) / r_star
    return PredictionDev(z2, floprc.astype(jnp.float32) / r_star, r_star,
                         f_star, z_star, total_flop)


@functools.partial(jax.jit, static_argnames=("max_deg_a", "max_deg_b"))
def reference_predict(a: CSRDevice, b: CSRDevice, rows: jax.Array,
                      max_deg_a: int, max_deg_b: int) -> PredictionDev:
    """Reference design (eq. 2): Z1* = z*/p."""
    floprc, total_flop = flop_per_row(a, b)
    cols, valid = gather_sampled_products(a, b, rows, max_deg_a, max_deg_b)
    z_star = count_distinct_sorted(cols).sum()
    f_star = valid.sum()
    p = rows.shape[0] / a.nrows
    z1 = z_star.astype(jnp.float32) / p
    cr = total_flop.astype(jnp.float32) / jnp.maximum(z1, 1.0)
    return PredictionDev(z1, floprc.astype(jnp.float32) / cr, cr, f_star, z_star, total_flop)


# --------------------------------------------------------------------------- #
# Binned prediction (DESIGN.md §4): per-bucket buffers instead of global pad.
# --------------------------------------------------------------------------- #
@functools.partial(jax.jit, static_argnames=("max_deg_a", "max_deg_b"))
def _bucket_counts(a: CSRDevice, b: CSRDevice, rownnz_b: jax.Array,
                   rows: jax.Array, max_deg_a: int, max_deg_b: int
                   ) -> tuple[jax.Array, jax.Array]:
    """(z, f) for one ESC bucket's sampled rows at the bucket's degree bounds.
    jit's static-arg cache keyed on the bucket signature IS the compile cache
    (see core.binning docstring)."""
    cols, valid = gather_sampled_products(a, b, rows, max_deg_a, max_deg_b,
                                          rownnz_b=rownnz_b)
    return count_distinct_sorted(cols).sum(), valid.sum()


@functools.partial(jax.jit, static_argnames=("max_deg_a", "max_deg_b",
                                             "span"))
def _bucket_counts_spa(a: CSRDevice, b: CSRDevice, rownnz_b: jax.Array,
                       rows: jax.Array, max_deg_a: int, max_deg_b: int,
                       span: int = 0) -> tuple[jax.Array, jax.Array]:
    """SPA-route twin of :func:`_bucket_counts`: dense presence instead of
    sort.  ``b.ncols`` is static (CSRDevice.shape is aux data)."""
    cols, valid = gather_sampled_products(a, b, rows, max_deg_a, max_deg_b,
                                          rownnz_b=rownnz_b)
    return count_distinct_dense(cols, b.ncols, span).sum(), valid.sum()


def binned_symbolic_counts(a: CSRDevice, b: CSRDevice, rows,
                           plan: BinningPlan, use_kernel: bool = False
                           ) -> tuple[jax.Array, jax.Array]:
    """Σ over buckets of the sampled (z*, f*), each bucket on its planned
    accumulator route — exact ints, so the totals equal the global-pad /
    all-ESC totals bit for bit whatever the per-bucket routing."""
    z = jnp.int32(0)
    f = jnp.int32(0)
    rownnz_b = jnp.diff(b.rpt)           # hoisted out of the per-bucket calls
    for bucket, sub in zip(plan.buckets, plan.subset(np.asarray(rows))):
        if sub.size == 0:
            continue            # no sampled rows landed in this bucket
        sub_d = jnp.asarray(sub)
        if use_kernel:
            from repro.kernels import ops as kops
            zb, fb, _ = kops.fused_flop_symbolic_routed(
                a, b, sub_d, max_deg_a=bucket.deg_a, max_deg_b=bucket.deg_b,
                route=bucket.route, span=bucket.span,
                block_samples=min(bucket.block_rows, 8), rownnz_b=rownnz_b)
        elif bucket.route == ROUTE_SPA:
            zb, fb = _bucket_counts_spa(a, b, rownnz_b, sub_d,
                                        bucket.deg_a, bucket.deg_b,
                                        bucket.span)
        else:
            zb, fb = _bucket_counts(a, b, rownnz_b, sub_d,
                                    bucket.deg_a, bucket.deg_b)
        z = z + zb.astype(jnp.int32)
        f = f + fb.astype(jnp.int32)
    return z, f


_binned_counts = binned_symbolic_counts      # backwards-compatible alias


@functools.partial(jax.jit, static_argnames=("max_deg_a", "max_deg_b",
                                             "route", "span"))
def _exact_rows_chunk(a: CSRDevice, b: CSRDevice, rownnz_b: jax.Array,
                      rows: jax.Array, max_deg_a: int, max_deg_b: int,
                      route: str, span: int) -> jax.Array:
    cols, _ = gather_sampled_products(a, b, rows, max_deg_a, max_deg_b,
                                      rownnz_b=rownnz_b)
    if route == ROUTE_SPA:
        return count_distinct_dense(cols, b.ncols, span)
    return count_distinct_sorted(cols)


def exact_row_counts(a: CSRDevice, b: CSRDevice, rows, *, max_deg_a: int,
                     max_deg_b: int, route: str = "", span: int = 0,
                     chunk: int = 256) -> np.ndarray:
    """EXACT output nnz per listed row — no sampling, no estimate.

    The same symbolic machinery as :func:`binned_symbolic_counts` (gather →
    distinct-count at the bucket's degree bounds, on the bucket's planned
    route) run over EVERY listed row instead of the sample, returning the
    per-row counts instead of the totals.  This is the guaranteed-sufficient
    capacity source of the retry escalation (DESIGN.md §9): a capacity set
    to ``max(exact_row_counts(...))`` cannot overflow, whatever the sampled
    predictor claimed.  Rows are processed in fixed-size chunks so the jit
    cache stays keyed on the bucket signature, not the bucket population.
    """
    rows = np.asarray(rows, dtype=np.int32)
    if rows.size == 0:
        return np.zeros(0, dtype=np.int64)
    rownnz_b = jnp.diff(b.rpt)
    chunk = int(min(chunk, ceil_pow2(rows.size)))   # pow2: bounded retraces
    pad = (-rows.size) % chunk
    padded = (np.concatenate([rows, np.full(pad, rows[-1], np.int32)])
              if pad else rows)
    out = []
    for lo in range(0, padded.size, chunk):
        cnt = _exact_rows_chunk(a, b, rownnz_b,
                                jnp.asarray(padded[lo:lo + chunk]),
                                int(max_deg_a), int(max_deg_b), str(route),
                                int(span))
        out.append(np.asarray(cnt, dtype=np.int64))
    return np.concatenate(out)[:rows.size]


def _binned_floprc(a: CSRDevice, b: CSRDevice, plan: BinningPlan) -> jax.Array:
    """floprC assembled bucket-by-bucket through the binned Pallas flop
    kernel — each bucket gathers at its own deg_a bound, not the global one."""
    from repro.kernels import ops as kops
    if not plan.buckets:
        return jnp.zeros(0, dtype=jnp.int32)
    parts = [kops.flop_rows(a, b, jnp.asarray(bucket.rows),
                            max_deg_a=bucket.deg_a,
                            block_rows=bucket.block_rows)
             for bucket in plan.buckets]
    return jnp.concatenate(parts)[plan.inverse_perm()]


def proposed_predict_binned(a: CSRDevice, b: CSRDevice, rows,
                            plan: BinningPlan,
                            use_kernel: bool = False,
                            floprc=None) -> PredictionDev:
    """THE PAPER'S METHOD (eq. 4), bucket-iterated.

    Identical outputs to :func:`proposed_predict` — z*/f* are exact integer
    counts whatever the padding, and the eq. 4 arithmetic is replayed on the
    same values — but each bucket's gather/sort buffer is (S_bin, DA_bin·DB_bin)
    instead of (S, DA·DB).  With ``use_kernel`` the per-bucket pass is the
    fused flop+symbolic Pallas kernel and floprC runs through the binned flop
    kernel.  ``floprc`` (Algorithm 1's per-row FLOP) may be passed in by
    callers that already computed it (the unified planner) to skip the
    redundant pass."""
    if floprc is not None:
        floprc = jnp.asarray(floprc)
        total_flop = jnp.sum(floprc)
    elif use_kernel:
        floprc = _binned_floprc(a, b, plan)
        total_flop = jnp.sum(floprc)
    else:
        floprc, total_flop = flop_per_row(a, b)
    z_star, f_star = _binned_counts(a, b, rows, plan, use_kernel)
    r_star = f_star.astype(jnp.float32) / jnp.maximum(z_star, 1).astype(jnp.float32)
    z2 = total_flop.astype(jnp.float32) / r_star
    return PredictionDev(z2, floprc.astype(jnp.float32) / r_star, r_star,
                         f_star, z_star, total_flop)


def reference_predict_binned(a: CSRDevice, b: CSRDevice, rows,
                             plan: BinningPlan,
                             use_kernel: bool = False) -> PredictionDev:
    """Reference design (eq. 2), bucket-iterated — mirrors reference_predict."""
    floprc, total_flop = flop_per_row(a, b)
    z_star, f_star = _binned_counts(a, b, rows, plan, use_kernel)
    p = np.asarray(rows).shape[0] / a.nrows
    z1 = z_star.astype(jnp.float32) / p
    cr = total_flop.astype(jnp.float32) / jnp.maximum(z1, 1.0)
    return PredictionDev(z1, floprc.astype(jnp.float32) / cr, cr, f_star,
                         z_star, total_flop)


# --------------------------------------------------------------------------- #
# Allocation planning: prediction → static buffer capacities (DESIGN.md §3).
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class AllocationPlan:
    """Static capacities for the numeric phase, derived from a prediction."""
    row_capacity: int       # per-row output slots (padded uniform rows)
    total_capacity: int     # total output slots if using compacted layout
    safety: float

    @staticmethod
    def from_prediction(pred_structure, flopr, safety: float = 1.2,
                        align: int = 8, pow2: bool = False) -> "AllocationPlan":
        import numpy as np
        ps = np.asarray(pred_structure, dtype=np.float64)
        fl = np.asarray(flopr, dtype=np.float64)
        # Never exceed the per-row upper bound; round to ``align`` lanes.
        per_row = np.minimum(np.ceil(ps * safety), fl)
        cap = int(per_row.max()) if per_row.size else 0
        cap = max(align, ((cap + align - 1) // align) * align)
        # alignment must never push past the upper bound (flopr is always safe)
        ub = int(fl.max()) if fl.size else cap
        cap = min(cap, max(ub, align))
        if pow2:
            # capacity half of the plan-cache quantization knob: ≤2× slot
            # inflation buys same-family different-seed executable sharing
            cap = ceil_pow2(cap)
        # fault-injection hook (core.faults): a no-op unless a test armed
        # capacity starvation — every planned output capacity funnels here
        cap = faults_mod.scale_capacity(cap)
        total = int(per_row.sum())
        total = max(align, ((total + align - 1) // align) * align)
        return AllocationPlan(cap, total, safety)


@dataclasses.dataclass(frozen=True)
class BinnedAllocationPlan:
    """Per-bucket output capacities for the binned numeric phase.

    The global plan sizes every row's slots by the worst predicted row in the
    whole matrix; the binned plan sizes each bucket by the worst predicted row
    *in that bucket*, so low-degree buckets keep small output buffers too."""

    bucket_capacities: tuple[int, ...]   # per-bucket row_capacity
    row_capacity: int                    # max — width of the assembled output
    total_capacity: int                  # Σ bucket rows · bucket capacity
    safety: float

    @staticmethod
    def from_prediction(plan: BinningPlan, pred_structure, flopr,
                        safety: float = 1.2, align: int = 8,
                        pow2: bool = False) -> "BinnedAllocationPlan":
        ps = np.asarray(pred_structure, dtype=np.float64)
        fl = np.asarray(flopr, dtype=np.float64)
        caps = []
        total = 0
        for bucket in plan.buckets:
            sub = AllocationPlan.from_prediction(
                ps[bucket.rows], fl[bucket.rows], safety=safety, align=align,
                pow2=pow2)
            caps.append(sub.row_capacity)
            total += bucket.n_rows * sub.row_capacity
        return BinnedAllocationPlan(
            bucket_capacities=tuple(caps),
            row_capacity=max(caps) if caps else align,
            total_capacity=total, safety=safety)


def shard_bucket_capacities(plan: BinningPlan, pred_structure, flopr,
                            bounds, safety: float = 1.2, align: int = 8,
                            pow2: bool = False, panel_structure=None,
                            panel_flopr=None
                            ) -> tuple[np.ndarray, tuple[int, ...]]:
    """Per-(bucket, shard) predicted row capacities for distributed execution.

    Returns ``(caps, static_caps)``: ``caps[i, s]`` is the capacity bucket
    ``i`` needs for the rows it owns inside shard ``s``'s contiguous row
    range (0 where the intersection is empty), sized by the same
    ``min(ceil(pred·safety), flopr)`` rule as :class:`AllocationPlan` but
    restricted to that intersection; ``static_caps[i]`` is the max over
    shards — the one static shape the SPMD executor can compile bucket ``i``
    with (pow2-rounded under ``pow2``, the plan-cache quantization knob).

    This replaces the legacy ``plan_distributed`` rule that sized every
    shard from the GLOBAL max predicted row: a hub row now inflates only its
    own (small) bucket's capacity, and every other bucket's buffers stay
    sized by their own rows — see the regression test in
    ``tests/test_plan.py``.

    **Column-partitioned B** (DESIGN.md §8): pass ``panel_structure`` /
    ``panel_flopr`` — each ``(n_panels, nrows)``, the per-panel predicted
    structure and per-panel FLOP from ``binning.panel_row_tables`` — and the
    capacity unit becomes (bucket, shard, panel): ``caps[i, s, p]`` sizes
    bucket ``i``'s output slots for shard ``s``'s rows restricted to panel
    ``p``.  ``static_caps[i]`` is then the max over (shard, panel) — a
    row's panel output is a subset of its full-row output, so panel static
    capacities are ≤ the full-row ones (the second buffer win of panels,
    after the B-footprint drop).
    """
    from .partition import shard_slices
    bounds = np.asarray(bounds)
    num_shards = bounds.size - 1
    # the replicated-B case is the 1-panel case: one sizing rule for both
    if panel_structure is not None:
        pps = np.asarray(panel_structure, dtype=np.float64)
        pfl = np.asarray(panel_flopr, dtype=np.float64)
    else:
        pps = np.asarray(pred_structure, dtype=np.float64)[None]
        pfl = np.asarray(flopr, dtype=np.float64)[None]
    n_panels = pps.shape[0]
    caps = np.zeros((len(plan.buckets), num_shards, n_panels),
                    dtype=np.int64)
    for i, bucket in enumerate(plan.buckets):
        lo, hi = shard_slices(bucket.rows, bounds)
        for s in range(num_shards):
            ids = bucket.rows[lo[s]:hi[s]]
            if not ids.size:
                continue
            for p in range(n_panels):
                caps[i, s, p] = AllocationPlan.from_prediction(
                    pps[p, ids], pfl[p, ids], safety=safety,
                    align=align).row_capacity
    if panel_structure is None:
        caps = caps[:, :, 0]
    if pow2:
        from .binning import ceil_pow2
        static_caps = tuple(ceil_pow2(int(max(align, caps[i].max())))
                            for i in range(len(plan.buckets)))
    else:
        static_caps = tuple(int(max(align, caps[i].max()))
                            for i in range(len(plan.buckets)))
    return caps, static_caps
