"""Beyond-paper application: MoE dispatch capacity from sampled CR (DESIGN §4).

Block-sparse MoE kernels (grouped/megablocks-style) materialize the dispatch
as a block-sparse structure over (token-group × expert): a block is nonzero
iff any token in the group routes to that expert.  Sizing the grouped-GEMM
buffers needs the number of nonzero blocks — exactly the paper's
"output structure" question, with

    FLOP  := token-level assignments   (exact & cheap: k per token)
    NNZ   := distinct (group, expert) blocks (needs the dedup pass)
    CR    := assignments per block  (the batching density)

The paper's estimator transfers verbatim: sample groups, compute the exact
sampled block count z* and sampled assignments f*, predict CR* = f*/z* and
   blocks* = total_assignments / CR*.

Host (numpy) for planning + a jnp twin for in-graph use/tests.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoECapacityPlan:
    predicted_blocks: float       # predicted nonzero (group, expert) blocks
    exact_sample_blocks: int      # z*
    sampled_assignments: int      # f*
    total_assignments: int        # F (exact)
    compression_ratio: float      # CR* = f*/z*
    per_expert_capacity: np.ndarray  # predicted token slots per expert

    def block_buffer_size(self, safety: float = 1.15) -> int:
        return int(np.ceil(self.predicted_blocks * safety))


def predict_dispatch_capacity(expert_ids: np.ndarray, num_experts: int,
                              group_size: int, seed: int = 0,
                              sample_fraction: float = 0.003,
                              sample_cap: int = 300) -> MoECapacityPlan:
    """``expert_ids``: (tokens, k) routed expert per token per top-k slot."""
    expert_ids = np.asarray(expert_ids)
    tokens, k = expert_ids.shape
    num_groups = max(1, tokens // group_size)
    total_assignments = tokens * k

    # exact per-expert assignment counts (the "FLOP per output row" analogue)
    flopr_e = np.bincount(expert_ids.reshape(-1), minlength=num_experts)

    # sample groups (with replacement, paper Algorithm 2 style)
    sample_num = max(1, min(int(sample_fraction * num_groups), sample_cap))
    rng = np.random.default_rng(seed)
    gids = (num_groups * rng.random(sample_num)).astype(np.int64).clip(0, num_groups - 1)

    f_star = 0
    z_star = 0
    for g in gids:
        sl = expert_ids[g * group_size:(g + 1) * group_size].reshape(-1)
        f_star += sl.size
        z_star += np.unique(sl).size
    cr = f_star / max(z_star, 1)
    predicted_blocks = total_assignments / cr
    per_expert = np.ceil(flopr_e / cr)
    return MoECapacityPlan(predicted_blocks, int(z_star), int(f_star),
                           int(total_assignments), float(cr), per_expert)


def predict_group_capacity(expert_ids: np.ndarray, num_experts: int,
                           group_size: int, seed: int = 0,
                           sample_fraction: float = 0.01,
                           sample_cap: int = 300,
                           safety: float = 1.1) -> int:
    """Per-(group, expert) token-slot capacity from sampled groups.

    The companion to ``predict_dispatch_capacity``: blocks* sizes the
    block-sparse buffer TOTAL; this sizes the static per-expert slot count
    that ``models.moe.apply_moe`` needs.  Samples groups (Algorithm 2 style),
    measures the max per-(group, expert) load on the sample, and adds a
    safety factor — replacing the blind ``capacity_factor`` guess with a
    measured statistic.
    """
    expert_ids = np.asarray(expert_ids)
    tokens, k = expert_ids.shape
    num_groups = max(1, tokens // group_size)
    sample_num = max(1, min(int(max(sample_fraction, 0.003) * num_groups),
                            sample_cap))
    rng = np.random.default_rng(seed)
    gids = (num_groups * rng.random(sample_num)).astype(np.int64).clip(
        0, num_groups - 1)
    peak = 0
    for g in gids:
        sl = expert_ids[g * group_size:(g + 1) * group_size].reshape(-1)
        peak = max(peak, int(np.bincount(sl, minlength=num_experts).max()))
    cap = int(np.ceil(peak * safety))
    return max(4, -(-cap // 4) * 4)


def exact_dispatch_blocks(expert_ids: np.ndarray, group_size: int) -> int:
    """Ground truth — the precise method (symbolic pass over all groups)."""
    expert_ids = np.asarray(expert_ids)
    tokens, k = expert_ids.shape
    num_groups = max(1, tokens // group_size)
    gid = (np.arange(tokens) // group_size).clip(0, num_groups - 1)
    keys = np.repeat(gid, k) * np.int64(expert_ids.max() + 2) + expert_ids.reshape(-1)
    return int(np.unique(keys).size)


def predict_dispatch_capacity_jnp(expert_ids: jnp.ndarray, num_experts: int,
                                  group_size: int, group_sample: jnp.ndarray):
    """In-graph twin (static sample count).  Returns (blocks*, CR*, flopr_e)."""
    tokens, k = expert_ids.shape
    total_assignments = tokens * k
    flopr_e = jnp.zeros(num_experts, jnp.int32).at[expert_ids.reshape(-1)].add(1)
    # gather sampled groups: (S, group_size*k)
    offs = jnp.arange(group_size, dtype=jnp.int32)
    tok_ix = group_sample[:, None] * group_size + offs[None, :]
    sl = expert_ids[jnp.clip(tok_ix, 0, tokens - 1)].reshape(group_sample.shape[0], -1)
    srt = jnp.sort(sl, axis=-1)
    distinct = 1 + ((srt[:, 1:] != srt[:, :-1]).astype(jnp.int32)).sum(-1)
    z_star = distinct.sum()
    f_star = sl.size
    cr = f_star / jnp.maximum(z_star, 1).astype(jnp.float32)
    return total_assignments / cr, cr, flopr_e
