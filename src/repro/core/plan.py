"""Unified SpGEMM planner/executor with a signature-keyed plan cache.

This module subsumes the previously scattered plan state (``BinningPlan`` +
``AllocationPlan`` / ``BinnedAllocationPlan`` + ``DistSpGEMMPlan``) into ONE
pipeline (DESIGN.md §6) that runs the paper's whole point end to end:

  1. **sample → predict**: the binned, routed sampled-CR predictor
     (``predictor.proposed_predict_binned``, eq. 4) — not the global-pad one;
  2. **partition on predicted nnz**: output rows split into ``num_shards``
     contiguous ranges with ~equal *predicted* output nnz
     (``partition.balanced_contiguous`` — the paper's load-balance claim);
  3. **capacities per bucket per shard**: each degree bucket's output buffer
     is sized from the prediction restricted to the rows that bucket owns
     inside each shard (``predictor.shard_bucket_capacities``) — a hub row
     inflates only its own (tiny) bucket, never another shard's buffers;
  4. **execute through the binned routed kernels**: both the single-device
     and the shard_map executor run every bucket through
     ``spgemm.routed_spgemm_rows`` (ESC sort / dense-SPA dispatch, optional
     Pallas kernels via ``kernels.ops``) — the PR 1/2 wins reach pod scale.

**Plan cache.** Executors are built once per *plan key* — the static half of
the compile contract: matrix shapes, device-CSR capacities (pow2-padded so
same-family matrices share them), the ordered per-bucket
``(signature, population, capacity)`` tuples (``RowBucket.signature`` is the
``BinningPlan.signatures()`` contract from DESIGN.md §4), and the mesh
fingerprint.  Repeated SpGEMMs over same-shaped bucket sets — the serving
scenario — look up the same jitted executable and run with ZERO retraces
(``PlanCache.stats()["traces"]`` is pinned by ``tests/test_plan.py`` /
``tests/test_distributed.py``).

**Population quantization** (``plan_spgemm(pop_quant=True)``, DESIGN.md §7).
The exact-population key above limits guaranteed reuse to structure-identical
pairs.  The quantization knob pow2-pads every varying shape in the key —
bucket populations (local row tables ride with a validity mask; distributed
``rows_pb`` pads its shard tables), degree bounds
(``binning.POW2_DEG_ALIGN``) and predicted capacities — so *same-family,
different-seed* matrices share executables at ≤2× row padding (hit rates
measured in ``benchmarks/plan_cache_bench.py`` → ``BENCH_plan_cache.json``).
:class:`PlanTemplate` goes further: it freezes one quantized plan's bucket
ladder as the family-level compile contract and grows it monotonically
(pow2, in place), so EVERY member planned after the last growth shares one
executor — 100% steady-state reuse on all suite families (bench-gated).

**Overflow re-planning** (``plan_spgemm(retry_safety=...)``, DESIGN.md §7).
The numeric kernels report each row's TRUE nnz even when its bucket's
capacity truncates the output, so after the numeric phase :func:`execute`
detects per-bucket (and per-shard) overflow host-side, bumps ONLY the
overflowing buckets' capacities (``×retry_safety^n``, pow2-rounded, floored
at the observed need) and re-executes just those buckets through cached
per-bucket executors, splicing the results back — the compiled-program
analogue of realloc, closing the paper's predict→allocate loop end to end.
Retry counts and final capacities are surfaced on the plan
(``plan.retries`` / ``plan.retry_events`` / ``plan.stats()``); the
no-overflow fast path costs one host readback of ``row_nnz`` and ZERO
retraces.

Public API::

    plan = plan_spgemm(a, b)                    # single device
    out  = execute(plan, a, b)                  # SpGEMMOut
    plan = plan_spgemm(a, b, mesh=mesh)         # distributed
    out  = execute(plan, a, b)                  # DistSpgemmOut
    c    = reassemble(plan, out, ncols=b.ncols) # host CSR
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.sparse.formats import CSR
from . import binning as binning_mod
from . import csr as csr_mod
from . import faults as faults_mod
from . import oracle
from . import partition as part_mod
from . import predictor as predictor_mod
from . import validate as validate_mod
from .csr import COL_SENTINEL, CSRDevice
from .errors import (CapacityExhaustedError, OperandValidationError,
                     PlanMismatchError, ShardFailureError, SpgemmError)
from .spgemm import (SpGEMMOut, PanelSpgemmOut, pad_to_capacity,
                     routed_spgemm_rows)


# --------------------------------------------------------------------------- #
# Plan cache — session-level executor registry keyed on plan signatures.
# --------------------------------------------------------------------------- #
class PlanCache:
    """Maps plan keys to compiled (jitted) executors.

    ``hits``/``misses`` count executor lookups; ``traces`` counts actual
    executor retraces (the executor bodies bump it while being traced), so a
    cache-served SpGEMM over a same-shaped bucket set shows ``traces``
    unchanged — the zero-retrace serving contract.
    """

    def __init__(self) -> None:
        self._executors: dict = {}
        self.hits = 0
        self.misses = 0
        self.traces = 0

    def executor(self, key, build):
        """Get-or-build the executor for ``key`` (hashable plan key)."""
        if key in self._executors:
            self.hits += 1
        else:
            self.misses += 1
            self._executors[key] = build()
        return self._executors[key]

    def _note_trace(self) -> None:
        self.traces += 1

    def stats(self) -> dict:
        return dict(size=len(self._executors), hits=self.hits,
                    misses=self.misses, traces=self.traces)

    def clear(self) -> None:
        self._executors.clear()
        self.hits = self.misses = self.traces = 0


_DEFAULT_CACHE = PlanCache()


def plan_cache() -> PlanCache:
    """The session-level default plan cache."""
    return _DEFAULT_CACHE


# --------------------------------------------------------------------------- #
# Retry escalation policy (DESIGN.md §9)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry escalation for the overflow re-planning loop.

    Replaces the raw ``retry_safety``/``max_retries`` pair: ``rounds``
    pow2-bump ladder rounds (``×growth^attempt``, floored at the observed
    need) with an optional per-round capacity ceiling ``max_capacity``;
    when the ladder exhausts (no budget, or every bump ceiling-clamped)
    and ``exact_fallback`` is on, the loop escalates ONCE to an exact
    symbolic count (``predictor.exact_row_counts``) for only the offending
    (bucket × panel) units — guaranteed termination in ≤ ``rounds``+1
    re-execute waves with bitwise-correct output, recorded in
    ``plan.stats()["degradations"]``.  Residual overflow after that (only
    possible with the fallback off) follows ``on_exhausted``: ``"raise"``
    surfaces a typed :class:`~repro.core.errors.CapacityExhaustedError`
    (distributed: :class:`~repro.core.errors.ShardFailureError` naming the
    shard/panel); ``"surface"`` is the legacy behavior — overflow stays on
    the result and :func:`reassemble` raises.
    """

    rounds: int = 4
    growth: float = 1.5
    max_capacity: int | None = None
    exact_fallback: bool = True
    on_exhausted: str = "raise"       # "raise" | "surface"

    def __post_init__(self):
        if self.rounds < 0:
            raise PlanMismatchError(f"RetryPolicy.rounds must be >= 0, got "
                                    f"{self.rounds}")
        if self.on_exhausted not in ("raise", "surface"):
            raise PlanMismatchError(
                f"RetryPolicy.on_exhausted must be 'raise' or 'surface', "
                f"got {self.on_exhausted!r}")

    def clamp(self, cap: int, new_cap: int) -> int:
        """Apply the per-round ceiling; never shrink below the current cap."""
        if self.max_capacity is None:
            return new_cap
        return min(new_cap, max(int(self.max_capacity), cap))


def _plan_key_id(plan) -> str | None:
    """Short stable fingerprint of ``plan.key`` for error context."""
    try:
        return format(hash(plan.key) & 0xFFFFFFFF, "08x")
    except Exception:
        return None


# --------------------------------------------------------------------------- #
# Plan dataclasses
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class BucketShardTable:
    """One bucket's static shard execution table (distributed plans).

    ``table[s]`` lists the bucket rows shard ``s`` computes, padded to the
    bucket's max per-shard population ``rows_pb`` by repeating the shard's
    last owned row (or any bucket row when the shard owns none — padded
    outputs are masked off by ``valid`` at reassembly/overflow time).
    """

    table: np.ndarray       # (num_shards, rows_pb) int32
    valid: np.ndarray       # (num_shards, rows_pb) bool
    capacity: int           # static per-row output slots (max per-shard need)

    @property
    def rows_pb(self) -> int:
        return int(self.table.shape[1])


@dataclasses.dataclass(eq=False)   # identity compare; plans match via .key
class SpgemmPlan:
    """The unified plan: prediction + partition + capacities + executor key."""

    binning: binning_mod.BinningPlan
    alloc: predictor_mod.BinnedAllocationPlan
    structure: np.ndarray           # predicted nnz per output row (float64)
    flopr: np.ndarray               # FLOP per output row (int64)
    predicted_nnz: float
    compression_ratio: float
    sample_rows: np.ndarray
    shape_a: tuple[int, int]
    shape_b: tuple[int, int]
    cap_a: int                      # device-CSR col/val capacity (pow2-padded)
    cap_b: int
    safety: float
    use_kernel: bool
    # plan-cache quantization + overflow re-planning (DESIGN.md §7)
    pop_quant: bool = False         # pow2-padded populations/degrees/caps
    retry_safety: float = 0.0       # 0 → replanning off; else capacity bump/round
    max_retries: int = 4
    retries: int = 0                # rounds the last execute() needed
    retry_events: list = dataclasses.field(default_factory=list)  # last execute()
    # failure containment (DESIGN.md §9)
    retry_policy: "RetryPolicy | None" = None   # None → re-planning off
    degradations: list = dataclasses.field(default_factory=list)  # last execute()
    validation: dict = dataclasses.field(
        default_factory=lambda: dict(operands_validated=0,
                                     fingerprint_checks=0))
    # distributed-only (num_shards == 0 → single device)
    num_shards: int = 0
    axis: str = "data"
    partition: part_mod.Partition | None = None
    shard_tables: tuple[BucketShardTable, ...] = ()
    shard_capacities: np.ndarray | None = None  # (buckets, shards) per-shard need
    mesh: object = None             # not part of the key (see _mesh_key)
    # column-partitioned B (DESIGN.md §8); n_panels == 0 → replicated-B mode
    n_panels: int = 0
    panels: part_mod.PanelPartition | None = None
    panel_deg_b: tuple = ()         # per-bucket panel deg_b bound (≤ full deg_b)
    panel_caps: np.ndarray | None = None   # (buckets, n_panels) current caps
    row_shards: int = 0             # distributed: num_shards // n_panels
    _panel_host: tuple | None = dataclasses.field(default=None, repr=False)
    _panel_caps_dev: tuple = ()     # single-device per-panel operand capacities
    _panel_gather: object = None    # PanelGather (distributed numeric operands)
    # cached structure-only device uploads: gather indices (distributed) or
    # per-panel rpt/col (single-device) — the two modes are exclusive
    _panel_dev: tuple | None = dataclasses.field(default=None, repr=False)
    _nnz_b: int = 0                 # planned B nnz (panel gather map validity)
    # (nnz, col-sum) fingerprints of the PLANNED operands: the panel gather
    # maps bake both structures in, so execute() rejects a swapped operand
    # instead of silently combining it with the wrong index maps
    _panel_a_fp: tuple | None = None
    _panel_b_fp: tuple | None = None
    _template: object = None        # PlanTemplate this plan was fit against
    _pop_override: tuple | None = dataclasses.field(default=None, repr=False)
    _device_args: tuple | None = dataclasses.field(default=None, repr=False)
    # ((host_a, host_b), (ad, bd)) from planning — execute() on the planned
    # operands reuses the prediction pass's upload instead of a second H2D
    _planned_pair: tuple | None = dataclasses.field(default=None, repr=False)

    @property
    def distributed(self) -> bool:
        return self.num_shards > 0

    def local_populations(self) -> tuple[int, ...]:
        """Per-bucket traced row counts of the local executor — the exact
        populations, their pow2 pads under ``pop_quant``, or the template's
        grown pads when planned against one."""
        if self._pop_override is not None:
            return self._pop_override
        if self.pop_quant:
            return tuple(binning_mod.ceil_pow2(bk.n_rows)
                         for bk in self.binning.buckets)
        return tuple(bk.n_rows for bk in self.binning.buckets)

    def device_args(self) -> tuple:
        """Executor row-table args (+ inverse perm for local plans; + validity
        masks under ``pop_quant``), uploaded once per plan — the cache-served
        serving path pays pure dispatch."""
        if self._device_args is None:
            if self.distributed:
                args = tuple(jnp.asarray(t.table) for t in self.shard_tables)
            elif self.pop_quant:
                # pow2-padded bucket tables (repeat-last fill) + validity
                # masks; the inverse perm indexes the PADDED concatenation so
                # assembly drops pad rows for free
                pops = self.local_populations()
                tables, masks, pos = [], [], []
                off = 0
                for bk, pop in zip(self.binning.buckets, pops):
                    ids = np.empty(pop, dtype=np.int32)
                    ids[:bk.n_rows] = bk.rows
                    ids[bk.n_rows:] = bk.rows[-1] if bk.n_rows else 0
                    tables.append(jnp.asarray(ids))
                    mask = np.zeros(pop, dtype=bool)
                    mask[:bk.n_rows] = True
                    masks.append(jnp.asarray(mask))
                    pos.append(off + np.arange(bk.n_rows, dtype=np.int64))
                    off += pop
                pos = (np.concatenate(pos) if pos
                       else np.zeros(0, dtype=np.int64))
                perm = jnp.asarray(
                    pos[self.binning.inverse_perm()].astype(np.int32))
                args = (perm,) + tuple(masks) + tuple(tables)
            else:
                perm = jnp.asarray(
                    self.binning.inverse_perm().astype(np.int32))
                args = (perm,) + tuple(jnp.asarray(bk.rows)
                                       for bk in self.binning.buckets)
            self._device_args = args
        return self._device_args

    @property
    def key(self) -> tuple:
        """The static half of the compile contract (mesh fingerprint added
        at executor-lookup time, see :func:`_executor_key`)."""
        if self.n_panels:
            # panel plans key on the panel layout (quantized edges), the
            # gathered-operand statics, and per-bucket panel degree bounds
            # and capacities — the whole numeric compile contract of §8
            if self.distributed:
                buckets = tuple(
                    (bk.signature, db, t.rows_pb, t.capacity)
                    for bk, db, t in zip(self.binning.buckets,
                                         self.panel_deg_b, self.shard_tables))
                pan = (self.panels.key, self.row_shards,
                       self._panel_gather.nref, self._panel_gather.ecap)
            else:
                buckets = tuple(
                    (bk.signature, db, pop,
                     tuple(int(c) for c in self.panel_caps[i]))
                    for i, (bk, db, pop) in enumerate(
                        zip(self.binning.buckets, self.panel_deg_b,
                            self.local_populations())))
                pan = (self.panels.key, self._panel_caps_dev)
            return ("spgemm-plan-panels", self.num_shards, self.axis,
                    self.use_kernel, self.pop_quant, self.shape_a,
                    self.shape_b, self.cap_a, buckets, pan)
        if self.distributed:
            buckets = tuple(
                (bk.signature, t.rows_pb, t.capacity)
                for bk, t in zip(self.binning.buckets, self.shard_tables))
        else:
            buckets = tuple(
                (bk.signature, pop, int(cap))
                for bk, pop, cap in zip(self.binning.buckets,
                                        self.local_populations(),
                                        self.alloc.bucket_capacities))
        return ("spgemm-plan", self.num_shards, self.axis, self.use_kernel,
                self.pop_quant, self.shape_a, self.shape_b,
                self.cap_a, self.cap_b,
                self.alloc.row_capacity, buckets)

    def shard_slots(self) -> int:
        """Output slots each shard allocates under this plan
        (Σ buckets rows_pb·capacity; SPMD — identical on every shard)."""
        if not self.distributed:
            return int(self.alloc.total_capacity)
        return int(sum(t.rows_pb * t.capacity for t in self.shard_tables))

    def to_device(self, m: CSR, which: str) -> CSRDevice:
        """Convert one operand at the plan's padded device capacity."""
        cap = self.cap_a if which == "a" else self.cap_b
        shape = self.shape_a if which == "a" else self.shape_b
        validate_mod.validate_csr(m, name=which)
        if m.shape != shape:
            raise PlanMismatchError(
                f"operand {which} shape {m.shape} != planned {shape}",
                operand=which, observed=list(m.shape), planned=list(shape),
                plan_key=_plan_key_id(self))
        if m.nnz > cap:
            raise PlanMismatchError(
                f"operand {which} nnz {m.nnz} exceeds planned device "
                f"capacity {cap}", operand=which, observed=int(m.nnz),
                planned=int(cap), plan_key=_plan_key_id(self))
        return csr_mod.to_device(m, capacity=cap)

    def stats(self) -> dict:
        out = dict(
            predicted_nnz=round(float(self.predicted_nnz), 1),
            compression_ratio=round(float(self.compression_ratio), 4),
            num_buckets=len(self.binning.buckets),
            lane_reduction=round(self.binning.lane_reduction, 3),
            route_rows=self.binning.route_rows(),
            bucket_capacities=list(self.alloc.bucket_capacities),
            total_capacity=int(self.alloc.total_capacity),
        )
        if self.distributed:
            out.update(
                num_shards=self.num_shards,
                imbalance=round(self.partition.imbalance, 4),
                shard_slots=self.shard_slots(),
                bucket_rows_per_shard=[t.rows_pb for t in self.shard_tables],
                shard_bucket_capacities=[t.capacity for t in self.shard_tables],
            )
        if self.pop_quant:
            real = max(1, sum(bk.n_rows for bk in self.binning.buckets))
            out.update(pop_quant=True,
                       row_padding=round(sum(self.local_populations()) / real, 4))
        if self.retry_safety > 0:
            out.update(
                retry_safety=self.retry_safety,
                retries=self.retries,
                retry_events=list(self.retry_events),
                final_capacities=(
                    [[int(c) for c in row] for row in self.panel_caps]
                    if self.n_panels else
                    [t.capacity for t in self.shard_tables]
                    if self.distributed else
                    list(self.alloc.bucket_capacities)),
            )
        if self.n_panels:
            out.update(
                n_panels=self.n_panels,
                panel_edges=[int(e) for e in self.panels.edges],
                panel_nnz=[int(n) for n in self.panels.panel_nnz],
            )
            if self.distributed:
                out.update(row_shards=self.row_shards,
                           comm=self.comm_stats())
        # failure-containment counters (DESIGN.md §9) — always present so
        # observability dashboards need no schema branching; every value is
        # JSON-serializable by construction.
        out.update(
            retries=int(self.retries),
            degradations=[dict(e) for e in self.degradations],
            validation=dict(self.validation),
        )
        return out

    def comm_stats(self) -> dict:
        """Per-device B footprint + gather volume of a panel-distributed plan
        vs the replicated-B executor — the §8 acceptance metric
        (``benchmarks/comm_bench.py`` → ``BENCH_comm.json``)."""
        if not (self.n_panels and self.distributed):
            raise PlanMismatchError(
                "comm_stats needs a distributed panel plan",
                plan_key=_plan_key_id(self))
        pg = self._panel_gather
        # index+value bytes per entry (int32 col + float32 val) + rpt words
        rep_bytes = self.cap_b * 8 + (self.shape_b[0] + 1) * 4
        dev_bytes = pg.ecap * 8 + (pg.nref + 1) * 4
        payload_max = int(pg.ref_nnz.max()) if pg.ref_nnz.size else 0
        return dict(
            n_panels=self.n_panels,
            devices=self.num_shards,
            row_shards=self.row_shards,
            replicated_b_bytes=int(rep_bytes),
            per_device_b_bytes=int(dev_bytes),
            footprint_reduction=round(rep_bytes / max(1, dev_bytes), 3),
            b_nnz=int(self._nnz_b),
            payload_entries_max=payload_max,
            payload_reduction=round(self._nnz_b / max(1, payload_max), 3),
            gathered_entries_total=int(pg.ref_nnz.sum()),
            gathered_bytes_total=int(pg.ref_nnz.sum()) * 8,
        )


class DistSpgemmOut(NamedTuple):
    """Distributed numeric-phase output: per-bucket stacked shard blocks."""

    cols: tuple        # per bucket: (num_shards, rows_pb, cap_b) int32
    vals: tuple        # per bucket: (num_shards, rows_pb, cap_b) float32
    row_nnz: tuple     # per bucket: (num_shards, rows_pb) int32 — true nnz
    shard_overflow: np.ndarray   # (num_shards,) int64 — valid rows only


# --------------------------------------------------------------------------- #
# Plan templates — the family-level compile contract (DESIGN.md §7).
#
# Per-component pow2 rounding cannot make two matrices share a key when the
# bucket LADDER itself differs (a width band present in one seed's histogram
# and absent in the other's, or a hub degree crossing a pow2 boundary).  A
# template freezes one quantized plan's static half — bucket signatures,
# padded populations, capacities, device-CSR caps — and other same-shape
# matrices plan AGAINST it: rows are assigned to the first template bucket
# whose degree bounds dominate them, populations/capacities grow (pow2,
# monotone, in place) only when a member exceeds the template, and every
# member planned after the last growth lands on the SAME plan key.
# --------------------------------------------------------------------------- #
class PlanTemplate:
    """Mutable static execution profile shared by a family of matrices.

    Build from a representative plan, then pass to
    ``plan_spgemm(template=...)``::

        tpl = PlanTemplate.from_plan(plan_spgemm(a0, b0, pop_quant=True))
        p1  = plan_spgemm(a1, b1, template=tpl)   # same key as a0·b0's plan
                                                  # unless a1/b1 outgrow it

    Growth events (``tpl.growths``) re-key subsequent plans once; members
    planned after the last growth all share executables.
    """

    def __init__(self, shape_a, shape_b, cap_a, cap_b, use_kernel, safety,
                 sigs, pops, caps):
        self.shape_a = tuple(shape_a)
        self.shape_b = tuple(shape_b)
        self.cap_a = int(cap_a)
        self.cap_b = int(cap_b)
        self.use_kernel = bool(use_kernel)
        self.safety = float(safety)
        self.sigs = list(sigs)      # per-bucket RowBucket.signature tuples
        self.pops = list(pops)      # pow2 padded populations
        self.caps = list(caps)      # pow2 row capacities
        self.growths = 0

    @staticmethod
    def from_plan(plan: "SpgemmPlan") -> "PlanTemplate":
        if not plan.pop_quant:
            raise PlanMismatchError("templates require a pop_quant=True plan",
                                    plan_key=_plan_key_id(plan))
        if plan.distributed:
            raise PlanMismatchError(
                "build templates from a single-device plan; "
                "pass mesh to plan_spgemm(template=...) instead",
                plan_key=_plan_key_id(plan))
        return PlanTemplate(
            plan.shape_a, plan.shape_b, plan.cap_a, plan.cap_b,
            plan.use_kernel, plan.safety,
            sigs=[bk.signature for bk in plan.binning.buckets],
            pops=list(plan.local_populations()),
            caps=list(plan.alloc.bucket_capacities))

    def _grow_sig(self, i: int, da: int, db: int, span: int,
                  lane_budget: int = binning_mod.DEFAULT_LANE_BUDGET) -> None:
        """Raise bucket ``i``'s static bounds to dominate (da, db, span)."""
        da0, db0, _, route, _, span0 = self.sigs[i]
        da = max(da0, binning_mod.ceil_pow2(da))
        db = max(db0, binning_mod.ceil_pow2(db))
        span = max(span0, binning_mod.ceil_pow2(span))
        blk = binning_mod._pick_block_rows(da * db, lane_budget,
                                           binning_mod.DEFAULT_MAX_BLOCK_ROWS)
        if route == binning_mod.ROUTE_SPA:
            tile, _ = binning_mod.spa_tile(span, lane_budget)
            blk = int(max(1, min(blk, binning_mod.floor_pow2(
                max(1, lane_budget // tile)))))
            self.sigs[i] = (da, db, blk, route, tile, span)
        else:
            self.sigs[i] = (da, db, blk, route, 0, 0)
        self.growths += 1

    def assign(self, deg_a: np.ndarray, dbmax: np.ndarray,
               spans: np.ndarray | None) -> np.ndarray:
        """Row → bucket index under degree-bound dominance (first/narrowest
        dominating bucket wins; -1 when no bucket covers the row)."""
        m = deg_a.size
        out = np.full(m, -1, dtype=np.int32)
        for i, (da, db, _blk, route, _tile, span) in enumerate(self.sigs):
            ok = (out < 0) & (deg_a <= da) & (dbmax <= db)
            if route == binning_mod.ROUTE_SPA and spans is not None:
                ok &= spans <= span
            out[ok] = i
        return out

    def fit(self, a, b) -> "binning_mod.BinningPlan":
        """Assign every row of ``a·b`` to a template bucket, growing the
        template (monotone, pow2) where the member exceeds it, and return
        the member's :class:`~repro.core.binning.BinningPlan` carrying the
        template's static bounds."""
        if a.shape != self.shape_a or b.shape != self.shape_b:
            raise PlanMismatchError(
                f"member shapes {a.shape}/{b.shape} do not match template "
                f"{self.shape_a}/{self.shape_b}",
                observed=[list(a.shape), list(b.shape)],
                planned=[list(self.shape_a), list(self.shape_b)])
        a_rpt = np.asarray(a.rpt)
        a_col = np.asarray(a.col)
        b_rpt = np.asarray(b.rpt)
        rownnz_b = np.diff(b_rpt.astype(np.int64))
        deg_a, dbmax, _width = binning_mod.row_widths(a_rpt, a_col, rownnz_b)
        need_spans = any(s[3] == binning_mod.ROUTE_SPA for s in self.sigs)
        spans = (binning_mod.row_spans(a_rpt, a_col, b_rpt,
                                       np.asarray(b.col))
                 if need_spans else None)
        which = self.assign(deg_a, dbmax, spans)
        if (which < 0).any():
            # grow the widest bucket to cover the escapees, then re-assign
            left = which < 0
            self._grow_sig(len(self.sigs) - 1,
                           int(deg_a[left].max(initial=1)),
                           int(dbmax[left].max(initial=1)),
                           int(spans[left].max(initial=1))
                           if spans is not None else 1)
            which = self.assign(deg_a, dbmax, spans)
            assert (which >= 0).all()
        buckets = []
        row_bucket = np.zeros(deg_a.size, dtype=np.int32)
        for i, sig in enumerate(self.sigs):
            ids = np.ascontiguousarray(
                np.flatnonzero(which == i).astype(np.int32))
            da, db, blk, route, tile, span = sig
            n_tiles = (-(-binning_mod.ceil_pow2(max(1, span)) // tile)
                       if route == binning_mod.ROUTE_SPA and tile else 0)
            buckets.append(binning_mod.RowBucket(
                rows=ids, deg_a=da, deg_b=db, block_rows=blk, route=route,
                tile_n=tile, n_tiles=n_tiles, span=span))
            row_bucket[ids] = i
            if ids.size > self.pops[i]:
                self.pops[i] = binning_mod.ceil_pow2(ids.size)
                self.growths += 1
        gda = int(deg_a.max()) if deg_a.size else 1
        gdb = int(rownnz_b.max()) if rownnz_b.size else 1
        return binning_mod.BinningPlan(
            buckets=tuple(buckets), nrows=deg_a.size,
            global_deg_a=max(1, gda), global_deg_b=max(1, gdb),
            row_bucket=row_bucket)

    def grow_caps(self, member_caps) -> None:
        for i, c in enumerate(member_caps):
            if int(c) > self.caps[i]:
                self.caps[i] = binning_mod.ceil_pow2(int(c))
                self.growths += 1

    def dist_profile(self, num_shards: int) -> dict:
        """Per-mesh-size static shard profile: pow2 ``rows_pb`` and per-shard
        capacities per bucket, grown monotonically like the local half
        (first use seeds from the member without counting growth)."""
        if not hasattr(self, "_dist"):
            self._dist = {}
        return self._dist.setdefault(
            int(num_shards), dict(rows_pb=[0] * len(self.sigs),
                                  caps=[0] * len(self.sigs)))

    def grow_dist(self, num_shards: int, rows_pb, caps) -> tuple[list, list]:
        d = self.dist_profile(num_shards)
        fresh = not any(d["rows_pb"])
        for i, (r, c) in enumerate(zip(rows_pb, caps)):
            if int(r) > d["rows_pb"][i]:
                d["rows_pb"][i] = binning_mod.ceil_pow2(int(r))
                self.growths += 0 if fresh else 1
            if int(c) > d["caps"][i]:
                d["caps"][i] = binning_mod.ceil_pow2(int(c))
                self.growths += 0 if fresh else 1
        return list(d["rows_pb"]), list(d["caps"])

    def grow_device_caps(self, nnz_a: int, nnz_b: int) -> None:
        if nnz_a > self.cap_a:
            self.cap_a = _device_capacity(nnz_a)
            self.growths += 1
        if nnz_b > self.cap_b:
            self.cap_b = _device_capacity(nnz_b)
            self.growths += 1

    def stats(self) -> dict:
        return dict(buckets=len(self.sigs), sigs=[list(s) for s in self.sigs],
                    pops=list(self.pops), caps=list(self.caps),
                    cap_a=self.cap_a, cap_b=self.cap_b, growths=self.growths)


# --------------------------------------------------------------------------- #
# Automatic template selection — a session registry keyed on a cheap
# structural sketch, so callers get template-level executor sharing without
# holding the PlanTemplate handle (``plan_spgemm(template="auto")``).
# --------------------------------------------------------------------------- #
def _structural_sketch(a, b) -> tuple:
    """Cheap structural fingerprint of an operand pair: exact shapes plus a
    vector of log2 degree-regime statistics (mean/median gather width, mean
    A degree, mean referenced-B degree).

    The shapes match EXACTLY (templates require it); the statistics are
    matched with a tolerance by :class:`TemplateRegistry` — any hard
    quantization boundary would split a family whose seed-to-seed jitter
    straddles it, which is exactly the fragmentation templates exist to
    remove.  Genuinely different degree regimes differ by ≥ 1 in these
    log2 stats and never match at the default tolerance."""
    rownnz_b = np.diff(np.asarray(b.rpt, dtype=np.int64))
    deg_a, dbmax, width = binning_mod.row_widths(
        np.asarray(a.rpt), np.asarray(a.col), rownnz_b)
    if width.size:
        vec = (float(np.log2(max(1.0, width.mean()))),
               float(np.log2(max(1.0, np.median(width)))),
               float(np.log2(max(1.0, deg_a.mean()))),
               float(np.log2(1.0 + dbmax.mean())))
    else:
        vec = (0.0, 0.0, 0.0, 0.0)
    return (tuple(a.shape), tuple(b.shape)), vec


class TemplateRegistry:
    """Session-level structural-sketch → :class:`PlanTemplate` map.

    ``plan_spgemm(template="auto")`` resolves the member's sketch here: a
    hit plans against the family's existing template (growing it if the
    member exceeds it), a miss seeds a fresh template from the member's own
    quantized plan.  Matching is shape-exact and TOLERANT on the degree
    statistics (within ``tol`` in log2 space), so same-family different-seed
    members always resolve to one template even when a statistic sits on a
    quantization boundary.  Steady state is the §7 template contract —
    every member planned after the family's last growth shares one
    executor — reached without any caller coordinating template handles.
    """

    def __init__(self, tol: float = 0.75) -> None:
        self.tol = float(tol)
        self._families: dict = {}    # shapes → [(stats_vec, PlanTemplate)]
        self.hits = 0
        self.misses = 0

    def _match(self, shapes, vec) -> PlanTemplate | None:
        for ref, tpl in self._families.get(shapes, ()):
            if max(abs(x - y) for x, y in zip(vec, ref)) <= self.tol:
                return tpl
        return None

    def lookup(self, a, b) -> PlanTemplate | None:
        return self._match(*_structural_sketch(a, b))

    def get_or_create(self, a, b, build) -> PlanTemplate:
        # sketch ONCE per call — it is an O(nnz) host pass over A
        shapes, vec = _structural_sketch(a, b)
        tpl = self._match(shapes, vec)
        if tpl is None:
            self.misses += 1
            tpl = build()
            self._families.setdefault(shapes, []).append((vec, tpl))
        else:
            self.hits += 1
        return tpl

    def stats(self) -> dict:
        tpls = [t for fam in self._families.values() for _, t in fam]
        return dict(size=len(tpls), hits=self.hits, misses=self.misses,
                    growths=sum(t.growths for t in tpls))

    def clear(self) -> None:
        self._families.clear()
        self.hits = self.misses = 0


_DEFAULT_REGISTRY = TemplateRegistry()


def template_registry() -> TemplateRegistry:
    """The session-level default template registry."""
    return _DEFAULT_REGISTRY


# --------------------------------------------------------------------------- #
# Planning
# --------------------------------------------------------------------------- #
def _device_capacity(nnz: int) -> int:
    """pow2-padded device-CSR capacity: same-family matrices land on the
    same padded capacity, keeping the executor's traced shapes — and hence
    the plan cache — shared across them."""
    return binning_mod.ceil_pow2(max(8, int(nnz)))


def _mesh_key(mesh) -> tuple:
    if mesh is None:
        return ()
    return (tuple(mesh.axis_names),
            tuple(int(d.id) for d in np.asarray(mesh.devices).flat))


def _executor_key(plan: SpgemmPlan, mesh) -> tuple:
    return plan.key + (_mesh_key(mesh),)


def _build_shard_tables(binplan: binning_mod.BinningPlan,
                        partn: part_mod.Partition,
                        static_caps,
                        pow2_rows: bool = False,
                        rows_pb_list=None,
                        slices=None) -> tuple[BucketShardTable, ...]:
    bounds = np.asarray(partn.bounds)
    num_shards = partn.num_parts
    tables = []
    for i, (bucket, cap) in enumerate(zip(binplan.buckets, static_caps)):
        lo, hi = (slices[i] if slices is not None
                  else part_mod.shard_slices(bucket.rows, bounds))
        counts = hi - lo
        rows_pb = int(max(1, counts.max())) if counts.size else 1
        if pow2_rows:
            # population quantization: pad rows_pb so same-family
            # different-seed plans share the shard executor's traced shapes
            rows_pb = binning_mod.ceil_pow2(rows_pb)
        if rows_pb_list is not None:
            # template profile: the family's grown rows_pb dominates
            rows_pb = max(rows_pb, int(rows_pb_list[i]))
        table = np.empty((num_shards, rows_pb), dtype=np.int32)
        valid = np.zeros((num_shards, rows_pb), dtype=bool)
        for s in range(num_shards):
            ids = bucket.rows[lo[s]:hi[s]]
            n = ids.size
            if n:
                table[s, :n] = ids
                table[s, n:] = ids[-1]
            else:
                # shard owns no rows of this bucket: pad with any bucket row
                # (stays inside the bucket's degree envelope; discarded) —
                # row 0 for a bucket emptied under a template
                table[s, :] = bucket.rows[0] if bucket.n_rows else 0
            valid[s, :n] = True
        tables.append(BucketShardTable(table=table, valid=valid,
                                       capacity=int(cap)))
    return tuple(tables)


# --------------------------------------------------------------------------- #
# Column-partitioned B (DESIGN.md §8): panel slicing + the ragged gather that
# replaces full operand replication in the distributed numeric phase.
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class PanelGather:
    """Structure-only half of the panel-gathered numeric operands.

    Built ONCE at plan time from the bucket row tables (host, launch-time —
    the materialized form of the ragged all-to-all): device ``d = s·P + p``
    (row shard ``s``, panel ``p``) receives ONLY the panel-``p`` entries of
    the B rows shard ``s``'s A-rows actually reference, as a compact CSR of
    ``nref`` rows.  A's column indices are remapped per row shard into the
    compact row space, so the unmodified gather kernels
    (``csr.expand_products``) run against the gathered operand unchanged.

    Index arrays are seed-structure only and upload once per plan; the
    value payload (``g_idx`` → ``b.val``) is re-gathered per execute, which
    is what lets a revalued serving pair reuse every compiled executor.
    """

    nref: int               # compact referenced-row count (padded, pow2 opt)
    ecap: int               # gathered entries per (shard, panel) (padded)
    row_shards: int
    n_panels: int
    a_col: np.ndarray       # (row_shards, cap_a) int32 remapped A columns
                            # (a shard's panels share one row)
    g_rpt: np.ndarray       # (D, nref+1) int32 compact panel row pointers
    g_col: np.ndarray       # (D, ecap) int32 absolute columns, sentinel pad
    g_idx: np.ndarray       # (D, ecap) int64 → b.val entry index, -1 pad
    ref_nnz: np.ndarray     # (D,) int64 true gathered entries (payload)


def _slice_panels(b: CSR, edges: np.ndarray) -> tuple:
    """Split host B into column panels in ONE pass.

    Returns per panel ``(prpt, pcol, pidx)``: CSR row pointers over B's rows
    restricted to the panel, the (absolute) column ids, and each entry's
    index into ``b.col``/``b.val`` — the shared substrate of the symbolic
    phase (per-panel degree tables) AND the numeric gather (the §8 dedup:
    panels are sliced once, never per phase)."""
    col = np.asarray(b.col, dtype=np.int64)
    pid = np.searchsorted(np.asarray(edges, dtype=np.int64), col,
                          side="right") - 1
    rows_of = np.repeat(np.arange(b.nrows, dtype=np.int64), np.diff(b.rpt))
    out = []
    for p in range(len(edges) - 1):
        idx = np.flatnonzero(pid == p)
        prpt = np.zeros(b.nrows + 1, dtype=np.int64)
        if idx.size:
            np.cumsum(np.bincount(rows_of[idx], minlength=b.nrows),
                      out=prpt[1:])
        out.append((prpt, b.col[idx].astype(np.int32), idx))
    return tuple(out)


def _build_panel_gather(a: CSR, pslices, bounds, row_shards: int,
                        n_panels: int, cap_a: int,
                        pop_quant: bool) -> PanelGather:
    """Materialize the per-device gathered-B operands (host, launch-time).

    One referenced-row set per row shard (union over its buckets — shared by
    every bucket, every panel, both phases and the retry loop), one entry
    gather per (shard, panel)."""
    bounds = np.asarray(bounds, dtype=np.int64)
    nrows_b = pslices[0][0].size - 1
    a_rpt = np.asarray(a.rpt, dtype=np.int64)
    a_col_host = np.asarray(a.col, dtype=np.int64)
    nnz_a = int(a_rpt[-1])
    refs = []
    for s in range(row_shards):
        seg = a_col_host[a_rpt[bounds[s]]:a_rpt[bounds[s + 1]]]
        refs.append(np.unique(seg))
    nref = max(1, max((r.size for r in refs), default=1))
    if pop_quant:
        nref = binning_mod.ceil_pow2(nref)
    d_total = row_shards * n_panels
    # one remapped-A row per ROW SHARD — a shard's panels share it; the
    # per-device (D, cap_a) layout is materialized only at upload time
    # (np.repeat in _panel_dist_args), not retained host-side
    a_col = np.zeros((row_shards, cap_a), dtype=np.int32)
    panel_rows = [np.repeat(np.arange(nrows_b, dtype=np.int64),
                            np.diff(prpt)) for prpt, _, _ in pslices]
    sel_cols, sel_idx, sel_cnt = [], [], []
    for s in range(row_shards):
        remap = np.zeros(max(1, nrows_b), dtype=np.int64)
        remap[refs[s]] = np.arange(refs[s].size)
        in_ref = np.zeros(max(1, nrows_b), dtype=bool)
        in_ref[refs[s]] = True
        if nnz_a:
            a_col[s, :nnz_a] = remap[a_col_host].astype(np.int32)
        for p in range(n_panels):
            prpt, pcol, pidx = pslices[p]
            sel = np.flatnonzero(in_ref[panel_rows[p]])
            sel_cols.append(pcol[sel])
            sel_idx.append(pidx[sel])
            # compact row pointers: panel entries are CSR-ordered, refs are
            # ascending, so selected entries sort by compact row already
            sel_cnt.append(np.bincount(remap[panel_rows[p][sel]],
                                       minlength=nref))
    ecap = max(8, max((c.size for c in sel_cols), default=0))
    if pop_quant:
        ecap = binning_mod.ceil_pow2(ecap)
    # fault-injection hook (core.faults): no-op unless a test armed gather
    # starvation — an under-sized entry cap is DETECTED below, never written
    # past (the typed error replaces a silent out-of-bounds fill)
    ecap = faults_mod.scale_gather_cap(ecap)
    g_rpt = np.zeros((d_total, nref + 1), dtype=np.int32)
    g_col = np.full((d_total, ecap), COL_SENTINEL, dtype=np.int32)
    g_idx = np.full((d_total, ecap), -1, dtype=np.int64)
    ref_nnz = np.zeros(d_total, dtype=np.int64)
    for d in range(d_total):
        e = sel_cols[d].size
        if e > ecap:
            raise ShardFailureError(
                f"panel gather entry capacity {ecap} cannot hold the "
                f"{e} entries device {d} references",
                shard=d // n_panels, panel=d % n_panels,
                observed=int(e), planned=int(ecap))
        np.cumsum(sel_cnt[d], out=g_rpt[d, 1:])
        g_col[d, :e] = sel_cols[d]
        g_idx[d, :e] = sel_idx[d]
        ref_nnz[d] = e
    return PanelGather(nref=nref, ecap=ecap, row_shards=row_shards,
                       n_panels=n_panels, a_col=a_col, g_rpt=g_rpt,
                       g_col=g_col, g_idx=g_idx, ref_nnz=ref_nnz)


def _gather_panel_values(pg: PanelGather, b: CSR) -> np.ndarray:
    """The per-execute half of the ragged all-to-all: ship each device ONLY
    its gathered panel's value payload (``ecap`` floats, vs ``cap_b``
    replicated) — index arrays never move after planning."""
    bval = np.asarray(b.val, dtype=np.float32)
    safe = np.clip(pg.g_idx, 0, max(0, bval.size - 1))
    vals = bval[safe] if bval.size else np.zeros(pg.g_idx.shape, np.float32)
    return np.where(pg.g_idx >= 0, vals, np.float32(0.0))


def _panel_meta(bucket: binning_mod.RowBucket, db_p: int, cap: int,
                lane_budget: int = binning_mod.DEFAULT_LANE_BUDGET) -> tuple:
    """Bucket execution metadata at the PANEL deg_b bound: the gather buffer
    shrinks from ``deg_a·deg_b`` to ``deg_a·db_p`` lanes (a row's panel
    products are a subset of its full products), so ``block_rows`` re-fits
    the narrower width under the same VMEM budget.  Route/tile/span stay as
    planned — outputs are route-invariant (DESIGN.md §5)."""
    blk = binning_mod._pick_block_rows(bucket.deg_a * db_p, lane_budget,
                                       binning_mod.DEFAULT_MAX_BLOCK_ROWS)
    if bucket.route == binning_mod.ROUTE_SPA and bucket.tile_n:
        blk = int(max(1, min(blk, binning_mod.floor_pow2(
            max(1, lane_budget // bucket.tile_n)))))
    return (bucket.deg_a, db_p, blk, bucket.route, bucket.tile_n,
            bucket.n_tiles, bucket.span, int(cap))


def plan_spgemm(a: CSR, b: CSR, *, mesh=None, num_shards: int | None = None,
                axis: str = "data", seed: int = 0, safety: float = 1.3,
                route: str = "auto", use_kernel: bool = False,
                sample_rows: np.ndarray | None = None,
                min_rows: int = binning_mod.DEFAULT_MIN_ROWS,
                deg_align: int = 1, pop_quant: bool = False,
                retry_safety: float = 0.0,
                max_retries: int = 4,
                retry_policy: "RetryPolicy | None" = None,
                validate: bool = True,
                template: "PlanTemplate | str | None" = None,
                registry: "TemplateRegistry | None" = None,
                n_panels: int = 0) -> SpgemmPlan:
    """Plan ``C = A·B``: sample → predict (binned, routed) → partition on
    predicted nnz → per-bucket(-per-shard) capacities.

    ``mesh``/``num_shards`` select distributed planning (``num_shards``
    alone plans without devices — useful for planning-time analysis; a mesh
    can then be supplied to :func:`execute`).  ``a``/``b`` are host ``CSR``;
    planning is a launch-time host step like ``core.partition``.

    ``pop_quant`` turns on plan-cache quantization: pow2-padded bucket
    populations / distributed ``rows_pb``, pow2 degree bounds and pow2
    capacities, so same-family different-seed matrices share executables at
    ≤2× row padding.  ``retry_safety`` > 0 arms the overflow re-planning
    loop in :func:`execute` (``×retry_safety^n`` pow2-rounded capacity bumps,
    only overflowing buckets re-execute, ≤ ``max_retries`` rounds).
    ``template`` (implies ``pop_quant``) plans against a
    :class:`PlanTemplate`'s frozen bucket ladder instead of the member's own
    width histogram — the strongest sharing: every member planned after the
    template's last growth lands on the SAME plan key.  Pass
    ``template="auto"`` to resolve the template from a
    :class:`TemplateRegistry` (default: the session registry) keyed on a
    cheap structural sketch — callers get steady-state executor reuse
    without holding the handle.

    ``n_panels`` > 0 selects **column-partitioned B** (DESIGN.md §8): B is
    split into ``n_panels`` contiguous column panels; the symbolic phase
    runs on per-panel degree tables and the numeric phase executes one
    (bucket × panel) unit at panel-bound buffer widths.  Distributed plans
    fold the panel axis onto the 1-D ``data`` axis — device ``d`` serves
    (row shard ``d // n_panels``, panel ``d % n_panels``) and receives ONLY
    the gathered panel entries its rows reference, replacing full B
    replication (``num_shards`` must be a multiple of ``n_panels``).
    """
    operands_validated = 0
    if validate:
        validate_mod.validate_pair(a, b)
        operands_validated = 2
    elif a.ncols != b.nrows:
        raise OperandValidationError(
            f"operand shapes {a.shape} and {b.shape} are incompatible "
            f"for A·B", observed=int(b.nrows), planned=int(a.ncols))
    if retry_policy is None and retry_safety > 0:
        # legacy knobs: the raw pair maps onto a ladder-only policy with the
        # pre-§9 surface-overflow behavior, so existing callers keep their
        # exact semantics
        retry_policy = RetryPolicy(rounds=int(max_retries),
                                   growth=float(retry_safety),
                                   exact_fallback=False,
                                   on_exhausted="surface")
    if isinstance(template, str):
        if template != "auto":
            raise PlanMismatchError(f"unknown template mode {template!r}")
        reg = registry if registry is not None else _DEFAULT_REGISTRY
        template = reg.get_or_create(a, b, lambda: PlanTemplate.from_plan(
            plan_spgemm(a, b, seed=seed, safety=safety, route=route,
                        use_kernel=use_kernel, sample_rows=sample_rows,
                        min_rows=min_rows, pop_quant=True)))
    if n_panels and (mesh is not None or num_shards):
        shards_chk = int(num_shards if num_shards else mesh.shape[axis])
        if shards_chk % int(n_panels):
            raise PlanMismatchError(
                f"n_panels={n_panels} must divide the mesh axis size "
                f"{shards_chk} (panels fold onto the data axis)",
                observed=int(shards_chk), planned=int(n_panels))
    if template is not None:
        pop_quant = True
        template.grow_device_caps(a.nnz, b.nnz)
        binplan = template.fit(a, b)
    else:
        if pop_quant and deg_align <= 1:
            # quantized plans need quantized degree bounds, or the per-bucket
            # signatures (exact degree maxima) would fragment the key anyway
            deg_align = binning_mod.POW2_DEG_ALIGN
        binplan = binning_mod.build_plan(a, b, route=route, min_rows=min_rows,
                                         deg_align=deg_align)
    flopr, total_flop = oracle.flop_per_row(a, b)
    if sample_rows is None:
        sample_rows = (oracle.sample_rows(a.nrows, seed) if a.nrows
                       else np.zeros(0, dtype=np.int64))
    sample_rows = np.asarray(sample_rows, dtype=np.int64)

    if template is not None:
        cap_a, cap_b = template.cap_a, template.cap_b
    else:
        cap_a = _device_capacity(a.nnz)
        cap_b = _device_capacity(b.nnz)
    devpair = None
    if total_flop > 0 and sample_rows.size:
        ad = csr_mod.to_device(a, capacity=cap_a)
        bd = csr_mod.to_device(b, capacity=cap_b)
        devpair = (ad, bd)
        pred = predictor_mod.proposed_predict_binned(
            ad, bd, jnp.asarray(sample_rows, dtype=jnp.int32), binplan,
            use_kernel=use_kernel, floprc=flopr)
        structure = np.asarray(pred.structure, dtype=np.float64)
        predicted_nnz = float(pred.nnz_total)
        cr = float(pred.compression_ratio)
        if not np.isfinite(structure).all() or cr <= 0:
            # sampled rows had no products (f* = 0): fall back to the
            # upper-bound structure — always safe, never over-allocates
            # past flopr by construction of the capacity rule.
            structure = flopr.astype(np.float64)
            predicted_nnz = float(total_flop)
            cr = 1.0
        # fault-injection hook (core.faults): no-op unless a test armed
        # sketch corruption — models an unlucky sample end to end
        structure, predicted_nnz, cr = faults_mod.corrupt_sketch(
            structure, predicted_nnz, cr)
    else:
        structure = np.zeros(a.nrows, dtype=np.float64)
        predicted_nnz = 0.0
        cr = 1.0

    alloc = predictor_mod.BinnedAllocationPlan.from_prediction(
        binplan, structure, flopr, safety=safety, pow2=pop_quant)
    if template is not None:
        # the family's grown capacities dominate the member's prediction
        template.grow_caps(alloc.bucket_capacities)
        caps = tuple(template.caps)
        alloc = predictor_mod.BinnedAllocationPlan(
            bucket_capacities=caps,
            row_capacity=max(caps) if caps else 8,
            total_capacity=sum(bk.n_rows * c
                               for bk, c in zip(binplan.buckets, caps)),
            safety=safety)

    plan = SpgemmPlan(
        binning=binplan, alloc=alloc, structure=structure, flopr=flopr,
        predicted_nnz=predicted_nnz, compression_ratio=cr,
        sample_rows=sample_rows, shape_a=a.shape, shape_b=b.shape,
        cap_a=cap_a, cap_b=cap_b, safety=safety, use_kernel=use_kernel,
        pop_quant=pop_quant,
        retry_safety=(retry_policy.growth if retry_policy is not None
                      else retry_safety),
        max_retries=(retry_policy.rounds if retry_policy is not None
                     else max_retries),
        retry_policy=retry_policy)
    plan.validation["operands_validated"] = operands_validated
    if template is not None:
        plan._template = template
        plan._pop_override = tuple(template.pops)
    if devpair is not None:
        if n_panels:
            # panel executes never touch a replicated device B — keeping the
            # prediction pass's upload would pin cap_b·8 bytes per plan, the
            # very replication §8 removes.  Drop it; keep A's upload and the
            # HOST references (they gate the structure-fingerprint check).
            plan._planned_pair = ((a, b), (devpair[0], None))
        else:
            plan._planned_pair = ((a, b), devpair)

    structure_p = flopr_p = None
    if n_panels:
        # -- column panels (§8): slice B once; per-panel degree tables feed
        # both the symbolic capacities and the numeric gather (the dedup) --
        panels = part_mod.column_panels(b, int(n_panels), quantize=pop_quant)
        pslices = _slice_panels(b, panels.edges)
        dbmax_p, flopr_p = binning_mod.panel_row_tables(
            a.rpt, a.col, [ps[0] for ps in pslices])
        # per-panel predicted structure: eq. 4 applied per panel with the
        # plan's sampled r* (flopr partitions exactly over panels, so the
        # panel predictions sum to the full-row prediction)
        structure_p = flopr_p.astype(np.float64) / max(float(cr), 1e-9)
        dbrow = dbmax_p.max(axis=0) if dbmax_p.size else np.zeros(0, np.int64)
        panel_align = binning_mod.POW2_DEG_ALIGN if pop_quant else deg_align
        plan.n_panels = int(n_panels)
        plan.panels = panels
        plan.panel_deg_b = tuple(
            binning_mod.round_deg(
                int(dbrow[bk.rows].max()) if bk.n_rows else 1, panel_align)
            for bk in binplan.buckets)
        plan._panel_host = pslices
        plan._nnz_b = int(b.nnz)
        plan._panel_a_fp = (int(a.nnz),
                            int(np.asarray(a.col, dtype=np.int64).sum()))
        plan._panel_b_fp = (int(b.nnz),
                            int(np.asarray(b.col, dtype=np.int64).sum()))

    if mesh is not None or num_shards:
        shards = int(num_shards if num_shards else mesh.shape[axis])
        row_shards = shards // int(n_panels) if n_panels else shards
        partn = part_mod.balanced_contiguous(structure, row_shards)
        caps_mat, static_caps = predictor_mod.shard_bucket_capacities(
            binplan, structure, flopr, partn.bounds, safety=safety,
            pow2=pop_quant, panel_structure=structure_p,
            panel_flopr=flopr_p)
        rows_pb_list = slices = None
        if template is not None:
            # member per-bucket rows_pb (pow2) → grow the family profile,
            # then pad every table to the grown profile (the shard slices
            # are computed once and reused for the table fill)
            slices = [part_mod.shard_slices(bucket.rows, partn.bounds)
                      for bucket in binplan.buckets]
            member_pb = []
            for lo, hi in slices:
                counts = hi - lo
                member_pb.append(binning_mod.ceil_pow2(
                    int(max(1, counts.max())) if counts.size else 1))
            rows_pb_list, static_caps = template.grow_dist(
                row_shards, member_pb, static_caps)
        plan.num_shards = shards
        plan.axis = axis
        plan.partition = partn
        tables = _build_shard_tables(binplan, partn, static_caps,
                                     pow2_rows=pop_quant,
                                     rows_pb_list=rows_pb_list,
                                     slices=slices)
        if n_panels:
            # fold the panel axis onto the data axis: device d = s·P + p
            # repeats row shard s's table for each of its P panels
            tables = tuple(BucketShardTable(
                table=np.repeat(t.table, int(n_panels), axis=0),
                valid=np.repeat(t.valid, int(n_panels), axis=0),
                capacity=t.capacity) for t in tables)
            plan.row_shards = row_shards
            plan.panel_caps = np.tile(
                np.asarray(static_caps, dtype=np.int64)[:, None],
                (1, int(n_panels)))
            plan._panel_gather = _build_panel_gather(
                a, pslices, partn.bounds, row_shards, int(n_panels), cap_a,
                pop_quant)
        plan.shard_tables = tables
        plan.shard_capacities = caps_mat
        plan.mesh = mesh
    elif n_panels:
        # single-device panel mode: per-(bucket, panel) capacities are the
        # executor statics (each unit runs standalone, no SPMD coupling)
        pc_mat, _ = predictor_mod.shard_bucket_capacities(
            binplan, structure, flopr, np.array([0, a.nrows]), safety=safety,
            panel_structure=structure_p, panel_flopr=flopr_p)
        pc = np.maximum(8, pc_mat[:, 0, :])
        if pop_quant:  # plain loop: np.vectorize dies on zero-bucket plans
            pc = np.array([[binning_mod.ceil_pow2(int(c)) for c in row]
                           for row in pc], dtype=np.int64).reshape(pc.shape)
        plan.panel_caps = pc.astype(np.int64)
        plan._panel_caps_dev = tuple(
            faults_mod.scale_gather_cap(_device_capacity(int(n)))
            for n in panels.panel_nnz)
    return plan


# --------------------------------------------------------------------------- #
# Executors (cache-built, trace-counted)
# --------------------------------------------------------------------------- #
def _bucket_meta(bucket: binning_mod.RowBucket, cap: int) -> tuple:
    """Hashable static execution metadata for one bucket."""
    return (bucket.deg_a, bucket.deg_b, bucket.block_rows, bucket.route,
            bucket.tile_n, bucket.n_tiles, bucket.span, int(cap))


def _run_bucket(ad: CSRDevice, bd: CSRDevice, rows: jax.Array, meta: tuple,
                use_kernel: bool) -> SpGEMMOut:
    deg_a, deg_b, block_rows, route, tile_n, n_tiles, span, cap = meta
    return routed_spgemm_rows(
        ad, bd, rows, row_capacity=cap, deg_a=deg_a, deg_b=deg_b,
        block_rows=block_rows, route=route, tile_n=tile_n, n_tiles=n_tiles,
        span=span, use_kernel=use_kernel)


def _build_local_executor(metas: tuple, cap_out: int, use_kernel: bool,
                          cache: PlanCache, masked: bool = False):
    """Single-device executor: per-bucket routed passes + one concat/perm
    assembly — the :func:`repro.core.spgemm.spgemm_binned` dataflow inside
    one cached jit (row ids and the inverse permutation stay traced so the
    compiled program serves every same-keyed plan).

    ``masked`` is the pop-quant variant: bucket tables arrive pow2-padded
    with validity masks; pad rows (repeat-last fill) are excluded from the
    overflow count and never selected by the padded-layout ``perm``.
    """
    nb = len(metas)

    @jax.jit
    def run(ad, bd, perm, *rest):
        cache._note_trace()
        masks = rest[:nb] if masked else (None,) * nb
        tables = rest[nb:] if masked else rest
        parts_c, parts_v, parts_n = [], [], []
        overflow = jnp.int32(0)
        for meta, rows, mask in zip(metas, tables, masks):
            c, v, n, of = _run_bucket(ad, bd, rows, meta, use_kernel)
            if masked:
                of = jnp.where(mask, jnp.maximum(n - meta[-1], 0), 0).sum()
            c, v = pad_to_capacity(c, v, cap_out)
            parts_c.append(c)
            parts_v.append(v)
            parts_n.append(n.astype(jnp.int32))
            overflow = overflow + of.astype(jnp.int32)
        return SpGEMMOut(jnp.concatenate(parts_c, axis=0)[perm],
                         jnp.concatenate(parts_v, axis=0)[perm],
                         jnp.concatenate(parts_n, axis=0)[perm],
                         overflow)

    return run


def _build_bucket_executor(meta: tuple, use_kernel: bool, cache: PlanCache):
    """One bucket's standalone executor — the re-planning loop's unit of
    re-execution (trace-counted like the full executors)."""

    @jax.jit
    def run(ad, bd, rows):
        cache._note_trace()
        return _run_bucket(ad, bd, rows, meta, use_kernel)

    return run


def _build_bucket_dist_executor(meta: tuple, mesh, axis: str,
                                use_kernel: bool, cache: PlanCache):
    """One bucket's shard_map executor — the distributed re-planning unit."""

    def shard_fn(ad, bd, table):
        cache._note_trace()
        c, v, n, _ = _run_bucket(ad, bd, table[0], meta, use_kernel)
        return c[None], v[None], n.astype(jnp.int32)[None]

    fn = shard_map(shard_fn, mesh=mesh,
                   in_specs=(P(), P(), P(axis, None)),
                   out_specs=(P(axis, None, None), P(axis, None, None),
                              P(axis, None)),
                   check_rep=False)
    return jax.jit(fn)


def _build_dist_executor(metas: tuple, mesh, axis: str, use_kernel: bool,
                         cache: PlanCache):
    """shard_map executor: every shard runs every bucket's routed pass over
    its own row table — the binned/routed backend at pod scale.  A/B are
    replicated (index/value arrays broadcast once, as in the legacy path);
    only the row tables are sharded.  Per-shard overflow is derived host-
    side from the returned true ``row_nnz`` and the plan's valid masks."""

    def shard_fn(ad, bd, *tables):
        cache._note_trace()
        outs = []
        for meta, table in zip(metas, tables):
            c, v, n, _ = _run_bucket(ad, bd, table[0], meta, use_kernel)
            outs.extend([c[None], v[None], n.astype(jnp.int32)[None]])
        return tuple(outs)

    nb = len(metas)
    in_specs = (P(), P()) + (P(axis, None),) * nb
    out_specs = tuple(s for _ in range(nb)
                      for s in (P(axis, None, None), P(axis, None, None),
                                P(axis, None)))
    fn = shard_map(shard_fn, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    return jax.jit(fn)


def _build_local_panel_executor(metas: tuple, use_kernel: bool,
                                cache: PlanCache, masked: bool = False):
    """Single-device panel executor: one routed pass per (bucket × panel),
    each at its own panel-bound gather width and its own per-panel capacity.
    Panels partition the column space, so no merge pass follows — the
    per-(bucket, panel) blocks ARE the output (:class:`PanelSpgemmOut`)."""
    nb = len(metas)

    @jax.jit
    def run(ad, bps, *rest):
        cache._note_trace()
        masks = rest[:nb] if masked else (None,) * nb
        tables = rest[nb:] if masked else rest
        cols, vals, nnzs = [], [], []
        overflow = jnp.int32(0)
        for pmetas, rows, mask in zip(metas, tables, masks):
            bc, bv, bn = [], [], []
            for bp, meta in zip(bps, pmetas):
                c, v, n, of = _run_bucket(ad, bp, rows, meta, use_kernel)
                if masked:
                    of = jnp.where(mask, jnp.maximum(n - meta[-1], 0), 0).sum()
                bc.append(c)
                bv.append(v)
                bn.append(n.astype(jnp.int32))
                overflow = overflow + of.astype(jnp.int32)
            cols.append(tuple(bc))
            vals.append(tuple(bv))
            nnzs.append(tuple(bn))
        return PanelSpgemmOut(tuple(cols), tuple(vals), tuple(nnzs), overflow)

    return run


def _build_panel_dist_executor(metas: tuple, shape_a, nref: int, ncols_b: int,
                               mesh, axis: str, use_kernel: bool,
                               cache: PlanCache):
    """shard_map executor for column-partitioned B (DESIGN.md §8).

    Device ``d = s·P + p`` runs row shard ``s``'s bucket tables against its
    GATHERED panel operand — a compact CSR of only the B rows shard ``s``
    references, panel ``p`` entries only — through the same routed per-bucket
    dispatch as every other executor.  A's value/rpt arrays stay replicated;
    A's column indices arrive remapped per device into the compact row
    space.  Nothing else in the kernel stack changes: ``expand_products``
    cannot tell a gathered panel from a full operand."""

    def shard_fn(a_rpt, a_val, a_col, g_rpt, g_col, g_val, *tables):
        cache._note_trace()
        ad = CSRDevice(rpt=a_rpt, col=a_col[0], val=a_val,
                       shape=tuple(shape_a))
        bd = CSRDevice(rpt=g_rpt[0], col=g_col[0], val=g_val[0],
                       shape=(nref, ncols_b))
        outs = []
        for meta, table in zip(metas, tables):
            c, v, n, _ = _run_bucket(ad, bd, table[0], meta, use_kernel)
            outs.extend([c[None], v[None], n.astype(jnp.int32)[None]])
        return tuple(outs)

    nb = len(metas)
    in_specs = (P(), P(), P(axis, None), P(axis, None), P(axis, None),
                P(axis, None)) + (P(axis, None),) * nb
    out_specs = tuple(s for _ in range(nb)
                      for s in (P(axis, None, None), P(axis, None, None),
                                P(axis, None)))
    fn = shard_map(shard_fn, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    return jax.jit(fn)


def _panel_operands_local(plan: SpgemmPlan, b: CSR) -> list:
    """Per-panel device CSRs at the plan's padded panel capacities.

    Structure (rpt + padded col) is seed-structure only: built and uploaded
    ONCE per plan (cached in ``_panel_dev``, the local twin of
    :func:`_panel_dist_args`); only the value payload re-gathers from ``b``
    per execute — the serving pair reuses executors AND index uploads."""
    if plan._panel_dev is None:
        structs = []
        for p, ((prpt, pcol, _), cap) in enumerate(
                zip(plan._panel_host, plan._panel_caps_dev)):
            if pcol.size > cap:
                raise CapacityExhaustedError(
                    f"panel {p} operand capacity {cap} cannot hold its "
                    f"{pcol.size} entries", panel=p,
                    observed=int(pcol.size), planned=int(cap),
                    plan_key=_plan_key_id(plan))
            col = np.full(cap, COL_SENTINEL, dtype=np.int32)
            col[:pcol.size] = pcol
            structs.append((jnp.asarray(prpt, dtype=jnp.int32),
                            jnp.asarray(col)))
        plan._panel_dev = tuple(structs)
    out = []
    bval = np.asarray(b.val, dtype=np.float32)
    for (rpt_d, col_d), (_, pcol, pidx), cap in zip(plan._panel_dev,
                                                    plan._panel_host,
                                                    plan._panel_caps_dev):
        val = np.zeros(cap, dtype=np.float32)
        val[:pcol.size] = bval[pidx]
        out.append(CSRDevice(rpt=rpt_d, col=col_d, val=jnp.asarray(val),
                             shape=b.shape))
    return out


def _panel_dist_args(plan: SpgemmPlan) -> tuple:
    """Structure-only device uploads of the panel gather (once per plan)."""
    if plan._panel_dev is None:
        pg = plan._panel_gather
        plan._panel_dev = (
            jnp.asarray(np.repeat(pg.a_col, pg.n_panels, axis=0)),
            jnp.asarray(pg.g_rpt), jnp.asarray(pg.g_col))
    return plan._panel_dev


def _check_panel_operand(plan: SpgemmPlan, m, which: str = "b") -> CSR:
    """Panel plans bake operand STRUCTURE into the gather maps (B's entry
    indices; distributed, also A's remapped columns), so a same-shape
    different-structure operand would silently produce a wrong matrix.
    Require the host CSR and match its (nnz, col-sum) fingerprint against
    the planned operand's."""
    shape = plan.shape_b if which == "b" else plan.shape_a
    fp = plan._panel_b_fp if which == "b" else plan._panel_a_fp
    plan.validation["fingerprint_checks"] += 1
    if not isinstance(m, CSR):
        raise PlanMismatchError(
            f"panel plans bake operand {which}'s structure into the gather "
            "maps — pass the host CSR operand, not a CSRDevice",
            operand=which, plan_key=_plan_key_id(plan))
    m_fp = (int(m.nnz), int(np.asarray(m.col, dtype=np.int64).sum()))
    if m.shape != shape or m_fp != fp:
        raise PlanMismatchError(
            f"operand {which} shape/structure {m.shape}/nnz={m.nnz} does "
            f"not match the planned operand ({shape}/nnz={fp[0]}) — the "
            "panel gather map is structure-specific; re-plan for a new "
            "sparsity pattern", operand=which, observed=list(m_fp),
            planned=list(fp), plan_key=_plan_key_id(plan))
    return m


def _coerce_one(plan: SpgemmPlan, m, which: str, idx: int) -> CSRDevice:
    cap = plan.cap_a if which == "a" else plan.cap_b
    shape = plan.shape_a if which == "a" else plan.shape_b
    if isinstance(m, CSRDevice):
        # a pre-converted operand must sit at the plan's padded
        # capacity, or the cached executor would silently retrace per
        # distinct nnz (voiding the zero-retrace serving contract) —
        # or worse, compute a different matrix without complaint
        if m.shape != shape or m.capacity != cap:
            raise PlanMismatchError(
                f"operand {which}: CSRDevice shape/capacity "
                f"{m.shape}/{m.capacity} does not match the plan's "
                f"{shape}/{cap} — convert with plan.to_device()",
                operand=which, observed=[list(m.shape), int(m.capacity)],
                planned=[list(shape), int(cap)],
                plan_key=_plan_key_id(plan))
        return m
    if plan._planned_pair is not None and m is plan._planned_pair[0][idx]:
        return plan._planned_pair[1][idx]
    return plan.to_device(m, which)


def _coerce_pair(plan: SpgemmPlan, a, b) -> tuple[CSRDevice, CSRDevice]:
    return _coerce_one(plan, a, "a", 0), _coerce_one(plan, b, "b", 1)


# --------------------------------------------------------------------------- #
# Overflow re-planning (DESIGN.md §7) + retry escalation (§9): bump ONLY the
# overflowing buckets' capacities and re-execute them — the realloc half of
# the paper's story; when the ladder exhausts, escalate once to an exact
# symbolic count for the offending units.
# --------------------------------------------------------------------------- #
def _bumped_capacity(cap: int, need: int, retry_safety: float,
                     attempt: int) -> int:
    """Safety-factor schedule ``×retry_safety^attempt``, floored at the
    observed need (``row_nnz`` is exact, so one round converges) and
    pow2-rounded so retry capacities stay cache-quantized."""
    sched = int(np.ceil(cap * (retry_safety ** attempt)))
    return binning_mod.ceil_pow2(max(need, sched, cap + 1))


def _policy_of(plan: SpgemmPlan) -> RetryPolicy:
    """The plan's escalation policy (legacy ``retry_safety``/``max_retries``
    fields resolve to a ladder-only, surface-overflow policy)."""
    if plan.retry_policy is not None:
        return plan.retry_policy
    return RetryPolicy(rounds=int(plan.max_retries),
                       growth=float(plan.retry_safety) or 1.5,
                       exact_fallback=False, on_exhausted="surface")


def _exact_capacity(need: int, cap: int) -> int:
    """Guaranteed-sufficient pow2 capacity for the exact-symbolic fallback
    (never below the current cap — splicing only widens buffers)."""
    return binning_mod.ceil_pow2(max(8, int(need), int(cap)))


def _invoke_executor(run, info: dict, *args):
    """Every executor dispatch funnels here: the fault-injection hook
    (``core.faults.check_executor``) fires pre-dispatch, and any exception
    out of the executor — injected or real — surfaces as a typed
    :class:`ShardFailureError` naming the dispatch unit instead of an
    anonymous traceback from inside a jitted program."""
    try:
        faults_mod.check_executor(info)
        return run(*args)
    except SpgemmError:
        raise
    except Exception as e:
        raise ShardFailureError(f"executor failed: {e}", **info) from e


def _replan_local(plan: SpgemmPlan, ad, bd, out: SpGEMMOut,
                  cache: PlanCache) -> SpGEMMOut:
    policy = _policy_of(plan)
    buckets = plan.binning.buckets
    caps = list(plan.alloc.bucket_capacities)
    n = np.asarray(out.row_nnz, dtype=np.int64)
    col = val = None                   # materialized on first splice only
    args = plan.device_args()
    tables = args[1 + len(buckets):] if plan.pop_quant else args[1:]
    plan.retries = 0
    plan.retry_events = []             # observability covers the LAST execute
    plan.degradations = []

    def splice(i, new_cap, c2, v2):
        nonlocal col, val
        bk = buckets[i]
        c2 = np.asarray(c2)[:bk.n_rows]
        v2 = np.asarray(v2)[:bk.n_rows]
        if new_cap > col.shape[1]:
            grow = new_cap - col.shape[1]
            col = np.concatenate(
                [col, np.full((col.shape[0], grow), COL_SENTINEL,
                              np.int32)], axis=1)
            val = np.concatenate(
                [val, np.zeros((val.shape[0], grow), np.float32)], axis=1)
        col[bk.rows, :new_cap] = c2
        val[bk.rows, :new_cap] = v2

    def rerun(i, new_cap, unit):
        bk = buckets[i]
        meta = _bucket_meta(bk, new_cap)
        pop = int(tables[i].shape[0])
        run = cache.executor(
            ("bucket-retry", plan.shape_a, plan.shape_b, plan.cap_a,
             plan.cap_b, plan.use_kernel, meta, pop),
            lambda m=meta: _build_bucket_executor(m, plan.use_kernel,
                                                 cache))
        c2, v2, _, _ = _invoke_executor(run, dict(unit=unit, bucket=i),
                                        ad, bd, tables[i])
        splice(i, new_cap, c2, v2)

    for attempt in range(1, policy.rounds + 1):
        bumps = []
        for i, bk in enumerate(buckets):
            if not bk.n_rows:
                continue
            need = int(n[bk.rows].max())
            if need <= caps[i]:
                continue
            new_cap = policy.clamp(
                caps[i], _bumped_capacity(caps[i], need, policy.growth,
                                          attempt))
            if new_cap > caps[i]:      # ceiling-clamped units wait for the
                bumps.append((i, need, new_cap))   # exact fallback instead
        if not bumps:
            break
        if col is None:
            col = np.asarray(out.col).copy()
            val = np.asarray(out.val).copy()
        plan.retries = attempt
        for i, need, new_cap in bumps:
            rerun(i, new_cap, "bucket-retry")
            plan.retry_events.append(dict(
                round=attempt, bucket=i, old_cap=caps[i], new_cap=new_cap,
                need=need))
            caps[i] = new_cap
    # ladder exhausted (no rounds left, or every bump ceiling-clamped):
    # escalate ONCE to an exact symbolic count for the offending buckets —
    # guaranteed-sufficient caps, bitwise-correct output (DESIGN.md §9)
    over = [i for i, bk in enumerate(buckets)
            if bk.n_rows and int(n[bk.rows].max()) > caps[i]]
    if over and policy.exact_fallback:
        if col is None:
            col = np.asarray(out.col).copy()
            val = np.asarray(out.val).copy()
        for i in over:
            bk = buckets[i]
            counts = predictor_mod.exact_row_counts(
                ad, bd, bk.rows, max_deg_a=bk.deg_a, max_deg_b=bk.deg_b,
                route=bk.route, span=bk.span)
            need = int(counts.max(initial=1))
            new_cap = _exact_capacity(need, caps[i] + 1)
            rerun(i, new_cap, "exact-fallback")
            plan.degradations.append(dict(
                kind="exact_symbolic", bucket=i, old_cap=int(caps[i]),
                new_cap=int(new_cap), need=int(need)))
            caps[i] = new_cap
    if col is None:
        if over and policy.on_exhausted == "raise":
            raise CapacityExhaustedError(
                f"retry escalation exhausted with {int(out.overflow)} "
                f"entries still dropped (buckets {over})", buckets=over,
                observed=int(out.overflow),
                planned=[int(caps[i]) for i in over],
                plan_key=_plan_key_id(plan))
        return out                     # fast path: nothing overflowed
    # final capacities + overflow recomputed against the bumped plan
    capv = np.zeros(n.shape[0], dtype=np.int64)
    for bk, cap in zip(buckets, caps):
        capv[bk.rows] = cap
    overflow = int(np.maximum(n - capv, 0).sum())
    plan.alloc = predictor_mod.BinnedAllocationPlan(
        bucket_capacities=tuple(caps), row_capacity=max(caps),
        total_capacity=sum(bk.n_rows * c for bk, c in zip(buckets, caps)),
        safety=plan.alloc.safety)
    if plan._template is not None:
        plan._template.grow_caps(caps)   # the family learns from the miss
    if overflow and policy.on_exhausted == "raise":
        bad = [i for i, bk in enumerate(buckets)
               if bk.n_rows and int(n[bk.rows].max()) > caps[i]]
        raise CapacityExhaustedError(
            f"retry escalation exhausted with {overflow} entries still "
            f"dropped (buckets {bad})", buckets=bad, observed=int(overflow),
            planned=[int(caps[i]) for i in bad], plan_key=_plan_key_id(plan))
    return SpGEMMOut(jnp.asarray(col), jnp.asarray(val), out.row_nnz,
                     jnp.int32(overflow))


def _replan_dist(plan: SpgemmPlan, ad, bd, out: DistSpgemmOut,
                 cache: PlanCache, mesh) -> DistSpgemmOut:
    policy = _policy_of(plan)
    buckets = plan.binning.buckets
    tables = list(plan.shard_tables)
    nnzs = [np.asarray(x, dtype=np.int64) for x in out.row_nnz]
    cols, vals = list(out.cols), list(out.vals)
    args = plan.device_args()
    plan.retries = 0
    plan.retry_events = []             # observability covers the LAST execute
    plan.degradations = []
    changed = False

    def rerun(i, new_cap, unit):
        t = tables[i]
        meta = _bucket_meta(buckets[i], new_cap)
        run = cache.executor(
            ("bucket-retry-dist", plan.shape_a, plan.shape_b, plan.cap_a,
             plan.cap_b, plan.use_kernel, meta, t.rows_pb, plan.axis,
             _mesh_key(mesh)),
            lambda m=meta: _build_bucket_dist_executor(
                m, mesh, plan.axis, plan.use_kernel, cache))
        c2, v2, _ = _invoke_executor(run, dict(unit=unit, bucket=i),
                                     ad, bd, args[i])
        cols[i], vals[i] = c2, v2
        tables[i] = dataclasses.replace(t, capacity=new_cap)

    for attempt in range(1, policy.rounds + 1):
        bumps = []
        for i, t in enumerate(tables):
            need = int(np.where(t.valid, nnzs[i], 0).max(initial=0))
            if need <= t.capacity:
                continue
            new_cap = policy.clamp(
                t.capacity, _bumped_capacity(t.capacity, need, policy.growth,
                                             attempt))
            if new_cap > t.capacity:
                bumps.append((i, need, new_cap))
        if not bumps:
            break
        plan.retries = attempt
        changed = True
        for i, need, new_cap in bumps:
            old_cap = tables[i].capacity
            rerun(i, new_cap, "bucket-retry")
            plan.retry_events.append(dict(
                round=attempt, bucket=i, old_cap=old_cap,
                new_cap=new_cap, need=need))
    # exact-symbolic escalation for units the ladder could not cover (§9)
    over = [i for i, t in enumerate(tables)
            if int(np.where(t.valid, nnzs[i], 0).max(initial=0)) > t.capacity]
    if over and policy.exact_fallback:
        changed = True
        for i in over:
            bk = buckets[i]
            counts = predictor_mod.exact_row_counts(
                ad, bd, bk.rows, max_deg_a=bk.deg_a, max_deg_b=bk.deg_b,
                route=bk.route, span=bk.span)
            need = int(counts.max(initial=1))
            old_cap = tables[i].capacity
            new_cap = _exact_capacity(need, old_cap + 1)
            rerun(i, new_cap, "exact-fallback")
            plan.degradations.append(dict(
                kind="exact_symbolic", bucket=i, old_cap=int(old_cap),
                new_cap=int(new_cap), need=int(need)))
    if not changed:
        if over and policy.on_exhausted == "raise":
            shards = [int(s) for s in
                      np.flatnonzero(np.asarray(out.shard_overflow))]
            raise ShardFailureError(
                f"retry escalation exhausted with "
                f"{int(np.asarray(out.shard_overflow).sum())} entries still "
                f"dropped on shards {shards}", shards=shards, buckets=over,
                observed=int(np.asarray(out.shard_overflow).sum()),
                plan_key=_plan_key_id(plan))
        return out                     # fast path: nothing overflowed
    plan.shard_tables = tuple(tables)  # reassemble reads the final widths
    if plan._template is not None:
        plan._template.grow_dist(plan.num_shards,
                                 [t.rows_pb for t in tables],
                                 [t.capacity for t in tables])
    overflow = np.zeros(plan.num_shards, dtype=np.int64)
    for t, n in zip(tables, nnzs):
        overflow += np.where(t.valid,
                             np.maximum(n - t.capacity, 0), 0).sum(axis=1)
    if overflow.sum() and policy.on_exhausted == "raise":
        shards = [int(s) for s in np.flatnonzero(overflow)]
        raise ShardFailureError(
            f"retry escalation exhausted with {int(overflow.sum())} entries "
            f"still dropped on shards {shards}", shards=shards,
            observed=int(overflow.sum()), plan_key=_plan_key_id(plan))
    return DistSpgemmOut(tuple(cols), tuple(vals), out.row_nnz, overflow)


def _replan_local_panels(plan: SpgemmPlan, ad, bps, out: PanelSpgemmOut,
                         cache: PlanCache) -> PanelSpgemmOut:
    """Single-device panel retry: the re-planning unit is (bucket × panel) —
    an overflow in one panel of one bucket re-executes ONLY that block (the
    other panels' outputs are reused verbatim), spliced by whole-block
    replacement since panel blocks are independent."""
    policy = _policy_of(plan)
    buckets = plan.binning.buckets
    npan = plan.n_panels
    caps = np.asarray(plan.panel_caps, dtype=np.int64).copy()
    nnzs = [[np.asarray(out.row_nnz[i][p], dtype=np.int64)
             for p in range(npan)] for i in range(len(buckets))]
    cols = [list(bc) for bc in out.cols]
    vals = [list(bv) for bv in out.vals]
    args = plan.device_args()
    tables = args[1 + len(buckets):] if plan.pop_quant else args[1:]
    plan.retries = 0
    plan.retry_events = []
    plan.degradations = []
    changed = False

    def rerun(i, p, new_cap, unit):
        bk = buckets[i]
        meta = _panel_meta(bk, plan.panel_deg_b[i], new_cap)
        pop = int(tables[i].shape[0])
        run = cache.executor(
            ("bucket-retry-panel", plan.shape_a, plan.shape_b,
             plan.cap_a, plan._panel_caps_dev[p], plan.use_kernel, meta,
             pop),
            lambda m=meta: _build_bucket_executor(m, plan.use_kernel,
                                                  cache))
        c2, v2, _, _ = _invoke_executor(
            run, dict(unit=unit, bucket=i, panel=p), ad, bps[p], tables[i])
        cols[i][p] = c2
        vals[i][p] = v2

    for attempt in range(1, policy.rounds + 1):
        bumps = []
        for i, bk in enumerate(buckets):
            if not bk.n_rows:
                continue
            for p in range(npan):
                need = int(nnzs[i][p][:bk.n_rows].max(initial=0))
                if need <= caps[i, p]:
                    continue
                new_cap = policy.clamp(
                    int(caps[i, p]),
                    _bumped_capacity(int(caps[i, p]), need, policy.growth,
                                     attempt))
                if new_cap > caps[i, p]:
                    bumps.append((i, p, need, new_cap))
        if not bumps:
            break
        plan.retries = attempt
        changed = True
        for i, p, need, new_cap in bumps:
            rerun(i, p, new_cap, "bucket-retry")
            plan.retry_events.append(dict(
                round=attempt, bucket=i, panel=p, old_cap=int(caps[i, p]),
                new_cap=new_cap, need=need))
            caps[i, p] = new_cap
    # exact-symbolic escalation per offending (bucket × panel) unit (§9)
    over = [(i, p) for i, bk in enumerate(buckets) if bk.n_rows
            for p in range(npan)
            if int(nnzs[i][p][:bk.n_rows].max(initial=0)) > caps[i, p]]
    if over and policy.exact_fallback:
        changed = True
        for i, p in over:
            bk = buckets[i]
            counts = predictor_mod.exact_row_counts(
                ad, bps[p], bk.rows, max_deg_a=bk.deg_a,
                max_deg_b=plan.panel_deg_b[i], route=bk.route, span=bk.span)
            need = int(counts.max(initial=1))
            new_cap = _exact_capacity(need, int(caps[i, p]) + 1)
            rerun(i, p, new_cap, "exact-fallback")
            plan.degradations.append(dict(
                kind="exact_symbolic", bucket=i, panel=p,
                old_cap=int(caps[i, p]), new_cap=int(new_cap),
                need=int(need)))
            caps[i, p] = new_cap
    if not changed:
        if over and policy.on_exhausted == "raise":
            raise CapacityExhaustedError(
                f"retry escalation exhausted with {int(out.overflow)} "
                f"entries still dropped (bucket×panel units {over})",
                buckets=[i for i, _ in over], observed=int(out.overflow),
                plan_key=_plan_key_id(plan))
        return out                     # fast path: nothing overflowed
    plan.panel_caps = caps
    overflow = 0
    for i, bk in enumerate(buckets):
        for p in range(npan):
            overflow += int(np.maximum(
                nnzs[i][p][:bk.n_rows] - caps[i, p], 0).sum())
    if overflow and policy.on_exhausted == "raise":
        bad = [(i, p) for i, bk in enumerate(buckets) if bk.n_rows
               for p in range(npan)
               if int(nnzs[i][p][:bk.n_rows].max(initial=0)) > caps[i, p]]
        raise CapacityExhaustedError(
            f"retry escalation exhausted with {overflow} entries still "
            f"dropped (bucket×panel units {bad})",
            buckets=[i for i, _ in bad], observed=int(overflow),
            plan_key=_plan_key_id(plan))
    return PanelSpgemmOut(tuple(tuple(bc) for bc in cols),
                          tuple(tuple(bv) for bv in vals),
                          out.row_nnz, jnp.int32(overflow))


def _replan_dist_panels(plan: SpgemmPlan, ad, g_val_host: np.ndarray,
                        out: DistSpgemmOut, cache: PlanCache
                        ) -> DistSpgemmOut:
    """Distributed panel retry: overflow is detected per (bucket × panel)
    across that panel's device column, and ONLY the offending (bucket ×
    panel) re-executes — one cached local per-bucket executor run per row
    shard, against the SAME gathered operands the SPMD pass used (no
    re-gather, no full-bucket SPMD re-run)."""
    policy = _policy_of(plan)
    pg = plan._panel_gather
    npan = plan.n_panels
    ncols_b = plan.shape_b[1]
    buckets = plan.binning.buckets
    tables = list(plan.shard_tables)
    caps = np.asarray(plan.panel_caps, dtype=np.int64).copy()
    # truncation threshold per (bucket, panel): the width the executor
    # ACTUALLY allocated — every panel of bucket i ran at t.capacity (the
    # max over panels after an earlier bump), which may exceed caps[i, p];
    # comparing against caps would re-execute blocks nothing truncated
    alloc = np.array([[int(t.capacity)] * npan for t in tables],
                     dtype=np.int64)
    nnzs = [np.asarray(x, dtype=np.int64) for x in out.row_nnz]  # (D, pb)
    cols = vals = None                 # materialized on first retry only
    plan.retries = 0
    plan.retry_events = []
    plan.degradations = []

    def shard_operands(s, d):
        ad_d = CSRDevice(rpt=ad.rpt, col=jnp.asarray(pg.a_col[s]),
                         val=ad.val, shape=plan.shape_a)
        bd_d = CSRDevice(rpt=jnp.asarray(pg.g_rpt[d]),
                         col=jnp.asarray(pg.g_col[d]),
                         val=jnp.asarray(g_val_host[d]),
                         shape=(pg.nref, ncols_b))
        return ad_d, bd_d

    def rerun(i, p, new_cap, unit):
        nonlocal cols, vals
        t = tables[i]
        meta = _panel_meta(buckets[i], plan.panel_deg_b[i], new_cap)
        run = cache.executor(
            ("bucket-retry-panel-dist", plan.shape_a, plan.shape_b,
             plan.cap_a, pg.nref, pg.ecap, plan.use_kernel, meta,
             t.rows_pb),
            lambda m=meta: _build_bucket_executor(m, plan.use_kernel,
                                                  cache))
        if new_cap > cols[i].shape[2]:
            grow = new_cap - cols[i].shape[2]
            cols[i] = np.concatenate(
                [cols[i], np.full(cols[i].shape[:2] + (grow,),
                                  COL_SENTINEL, np.int32)], axis=2)
            vals[i] = np.concatenate(
                [vals[i], np.zeros(vals[i].shape[:2] + (grow,),
                                   np.float32)], axis=2)
        for s in range(plan.row_shards):
            d = s * npan + p
            ad_d, bd_d = shard_operands(s, d)
            c2, v2, _, _ = _invoke_executor(
                run, dict(unit=unit, bucket=i, panel=p, shard=s),
                ad_d, bd_d, jnp.asarray(t.table[d]))
            cols[i][d, :, :new_cap] = np.asarray(c2)
            vals[i][d, :, :new_cap] = np.asarray(v2)

    for attempt in range(1, policy.rounds + 1):
        bumps = []
        for i, t in enumerate(tables):
            for p in range(npan):
                need = int(np.where(t.valid[p::npan], nnzs[i][p::npan],
                                    0).max(initial=0))
                if need <= alloc[i, p]:
                    continue
                new_cap = policy.clamp(
                    int(alloc[i, p]),
                    _bumped_capacity(int(caps[i, p]), need, policy.growth,
                                     attempt))
                if new_cap > alloc[i, p]:
                    bumps.append((i, p, need, new_cap))
        if not bumps:
            break
        if cols is None:
            cols = [np.asarray(c).copy() for c in out.cols]
            vals = [np.asarray(v).copy() for v in out.vals]
        plan.retries = attempt
        for i, p, need, new_cap in bumps:
            rerun(i, p, new_cap, "bucket-retry")
            plan.retry_events.append(dict(
                round=attempt, bucket=i, panel=p, old_cap=int(caps[i, p]),
                new_cap=new_cap, need=need))
            caps[i, p] = new_cap
            alloc[i, p] = new_cap
    # exact-symbolic escalation per offending (bucket × panel) unit, one
    # cached local executor run per row shard against the SAME gathered
    # operands the SPMD pass used (§9)
    over = []
    for i, t in enumerate(tables):
        for p in range(npan):
            need = int(np.where(t.valid[p::npan], nnzs[i][p::npan],
                                0).max(initial=0))
            if need > alloc[i, p]:
                over.append((i, p))
    if over and policy.exact_fallback:
        if cols is None:
            cols = [np.asarray(c).copy() for c in out.cols]
            vals = [np.asarray(v).copy() for v in out.vals]
        for i, p in over:
            bk = buckets[i]
            t = tables[i]
            need = 1
            for s in range(plan.row_shards):
                d = s * npan + p
                rows = t.table[d][t.valid[d]]
                if not rows.size:
                    continue
                ad_d, bd_d = shard_operands(s, d)
                counts = predictor_mod.exact_row_counts(
                    ad_d, bd_d, rows, max_deg_a=bk.deg_a,
                    max_deg_b=plan.panel_deg_b[i], route=bk.route,
                    span=bk.span)
                need = max(need, int(counts.max(initial=1)))
            new_cap = _exact_capacity(need, int(alloc[i, p]) + 1)
            rerun(i, p, new_cap, "exact-fallback")
            plan.degradations.append(dict(
                kind="exact_symbolic", bucket=i, panel=p,
                old_cap=int(caps[i, p]), new_cap=int(new_cap),
                need=int(need)))
            caps[i, p] = new_cap
            alloc[i, p] = new_cap
    if cols is None:
        if over and policy.on_exhausted == "raise":
            total = int(np.asarray(out.shard_overflow).sum())
            raise ShardFailureError(
                f"retry escalation exhausted with {total} entries still "
                f"dropped (bucket×panel units {over})",
                shards=[int(d) // npan for d in
                        np.flatnonzero(np.asarray(out.shard_overflow))],
                observed=total, plan_key=_plan_key_id(plan))
        return out                     # fast path: nothing overflowed
    plan.panel_caps = caps
    plan.shard_tables = tuple(
        dataclasses.replace(t, capacity=int(caps[i].max()))
        for i, t in enumerate(tables))
    dev_panel = np.arange(plan.num_shards) % npan
    overflow = np.zeros(plan.num_shards, dtype=np.int64)
    for i, t in enumerate(plan.shard_tables):
        # residual TRUNCATION (vs the allocated widths) — entries a block
        # narrower than its true nnz actually dropped, not bookkeeping caps
        cap_d = alloc[i, dev_panel][:, None]
        overflow += np.where(t.valid,
                             np.maximum(nnzs[i] - cap_d, 0), 0).sum(axis=1)
    if overflow.sum() and policy.on_exhausted == "raise":
        devs = np.flatnonzero(overflow)
        raise ShardFailureError(
            f"retry escalation exhausted with {int(overflow.sum())} entries "
            "still dropped",
            shards=[int(d) // npan for d in devs],
            observed=int(overflow.sum()), plan_key=_plan_key_id(plan))
    return DistSpgemmOut(tuple(cols), tuple(vals), out.row_nnz, overflow)


def execute(plan: SpgemmPlan, a, b, *, mesh=None, cache: PlanCache | None = None):
    """Run the planned numeric phase.

    Single-device plans return a :class:`repro.core.spgemm.SpGEMMOut`;
    distributed plans return a :class:`DistSpgemmOut` (feed to
    :func:`reassemble`).  ``a``/``b`` may be host ``CSR`` (converted at the
    plan's padded capacities) or pre-converted ``CSRDevice``.  Executors are
    served from ``cache`` (default: the session cache) keyed on the plan's
    static signature — a second same-keyed plan reuses the compiled
    executable with zero retraces.

    Plans armed with ``retry_safety`` run the overflow re-planning loop: any
    bucket whose true ``row_nnz`` exceeded its capacity is re-executed at a
    bumped (pow2-rounded) capacity and spliced back — the plan's capacities
    are updated in place, so a subsequent :func:`execute` of the same plan
    allocates right the first time.
    """
    cache = cache if cache is not None else _DEFAULT_CACHE
    if plan.n_panels:
        # the fingerprint check is an O(nnz) host pass — the PLANNED
        # operands (the common serving identity) skip it for free
        planned = plan._planned_pair[0] if plan._planned_pair is not None \
            else (None, None)
        if b is not planned[1]:
            b = _check_panel_operand(plan, b, "b")
        if plan.distributed and a is not planned[0]:
            # the gather baked A's remapped columns too — an A with a
            # different structure would pair its values with the plan's
            # index maps and compute a different matrix without complaint
            a = _check_panel_operand(plan, a, "a")
        ad = _coerce_one(plan, a, "a", 0)
        bd = None                      # B never replicates in panel mode
    else:
        ad, bd = _coerce_pair(plan, a, b)
    if not plan.binning.buckets:
        if plan.distributed:
            return DistSpgemmOut((), (), (),
                                 np.zeros(plan.num_shards, dtype=np.int64))
        if plan.n_panels:
            return PanelSpgemmOut((), (), (), jnp.int32(0))
        cap = plan.alloc.row_capacity
        return SpGEMMOut(jnp.full((0, cap), COL_SENTINEL, jnp.int32),
                         jnp.zeros((0, cap), jnp.float32),
                         jnp.zeros((0,), jnp.int32), jnp.int32(0))

    if not plan.distributed:
        if plan.n_panels:
            metas = tuple(
                tuple(_panel_meta(bk, plan.panel_deg_b[i],
                                  int(plan.panel_caps[i, p]))
                      for p in range(plan.n_panels))
                for i, bk in enumerate(plan.binning.buckets))
            run = cache.executor(
                _executor_key(plan, None),
                lambda: _build_local_panel_executor(
                    metas, plan.use_kernel, cache, masked=plan.pop_quant))
            bps = _panel_operands_local(plan, b)
            out = _invoke_executor(run, dict(unit="local-panels"),
                                   ad, bps, *plan.device_args()[1:])
            if plan.retry_policy is not None or plan.retry_safety > 0:
                out = _replan_local_panels(plan, ad, bps, out, cache)
            return out
        metas = tuple(_bucket_meta(bk, cap)
                      for bk, cap in zip(plan.binning.buckets,
                                         plan.alloc.bucket_capacities))
        run = cache.executor(
            _executor_key(plan, None),
            lambda: _build_local_executor(metas, plan.alloc.row_capacity,
                                          plan.use_kernel, cache,
                                          masked=plan.pop_quant))
        out = _invoke_executor(run, dict(unit="local"),
                               ad, bd, *plan.device_args())
        if plan.retry_policy is not None or plan.retry_safety > 0:
            out = _replan_local(plan, ad, bd, out, cache)
        return out

    mesh = mesh if mesh is not None else plan.mesh
    if mesh is None:
        raise PlanMismatchError(
            "distributed plan needs a mesh (plan_spgemm(mesh=...)"
            " or execute(..., mesh=...))", plan_key=_plan_key_id(plan))
    if int(mesh.shape[plan.axis]) != plan.num_shards:
        raise PlanMismatchError(
            f"plan was built for {plan.num_shards} shards but mesh axis "
            f"{plan.axis!r} has {int(mesh.shape[plan.axis])} devices — "
            "re-plan with this mesh",
            observed=int(mesh.shape[plan.axis]), planned=plan.num_shards,
            plan_key=_plan_key_id(plan))
    if plan.n_panels:
        pg = plan._panel_gather
        metas = tuple(_panel_meta(bk, db, t.capacity)
                      for bk, db, t in zip(plan.binning.buckets,
                                           plan.panel_deg_b,
                                           plan.shard_tables))
        run = cache.executor(
            _executor_key(plan, mesh),
            lambda: _build_panel_dist_executor(
                metas, plan.shape_a, pg.nref, plan.shape_b[1], mesh,
                plan.axis, plan.use_kernel, cache))
        g_val_host = _gather_panel_values(pg, b)
        a_col_d, g_rpt_d, g_col_d = _panel_dist_args(plan)
        flat = _invoke_executor(run, dict(unit="dist-panels"),
                                ad.rpt, ad.val, a_col_d, g_rpt_d, g_col_d,
                                jnp.asarray(g_val_host), *plan.device_args())
        cols, vals, nnzs = flat[0::3], flat[1::3], flat[2::3]
        overflow = np.zeros(plan.num_shards, dtype=np.int64)
        for t, n in zip(plan.shard_tables, nnzs):
            over = np.maximum(np.asarray(n, dtype=np.int64) - t.capacity, 0)
            overflow += np.where(t.valid, over, 0).sum(axis=1)
        out = DistSpgemmOut(tuple(cols), tuple(vals), tuple(nnzs), overflow)
        if plan.retry_policy is not None or plan.retry_safety > 0:
            out = _replan_dist_panels(plan, ad, g_val_host, out, cache)
        return out
    metas = tuple(_bucket_meta(bk, t.capacity)
                  for bk, t in zip(plan.binning.buckets, plan.shard_tables))
    run = cache.executor(
        _executor_key(plan, mesh),
        lambda: _build_dist_executor(metas, mesh, plan.axis,
                                     plan.use_kernel, cache))
    flat = _invoke_executor(run, dict(unit="dist"),
                            ad, bd, *plan.device_args())
    cols, vals, nnzs = flat[0::3], flat[1::3], flat[2::3]
    overflow = np.zeros(plan.num_shards, dtype=np.int64)
    for t, n in zip(plan.shard_tables, nnzs):
        over = np.maximum(np.asarray(n, dtype=np.int64) - t.capacity, 0)
        overflow += np.where(t.valid, over, 0).sum(axis=1)
    out = DistSpgemmOut(tuple(cols), tuple(vals), tuple(nnzs), overflow)
    if plan.retry_policy is not None or plan.retry_safety > 0:
        out = _replan_dist(plan, ad, bd, out, cache, mesh)
    return out


# --------------------------------------------------------------------------- #
# Reassembly (host-side; tests/examples)
# --------------------------------------------------------------------------- #
def _check_overflow(total: int, per_shard, on_overflow: str) -> None:
    if on_overflow not in ("raise", "ignore"):
        raise PlanMismatchError(f"on_overflow must be 'raise' or 'ignore', "
                                f"got {on_overflow!r}")
    if total and on_overflow == "raise":
        shards = [int(s) for s in np.asarray(per_shard)]
        raise CapacityExhaustedError(
            f"SpGEMM overflow: {total} entries dropped "
            f"(per shard: {shards}); re-plan with a higher safety factor "
            "or pass on_overflow='ignore'",
            observed=int(total), shards=shards)


def reassemble(plan: SpgemmPlan, out, ncols: int | None = None, *,
               on_overflow: str = "raise") -> CSR:
    """Stitch an :func:`execute` result back into one host CSR.

    Accepts a local ``SpGEMMOut`` or a distributed ``DistSpgemmOut``.
    Overflow (entries dropped for capacity) RAISES by default instead of
    silently truncating the result — pass ``on_overflow="ignore"`` to get
    the truncated matrix anyway.
    """
    ncols = int(ncols if ncols is not None else plan.shape_b[1])
    nrows = plan.shape_a[0]
    rows_out = [np.zeros(0, np.int64)]
    cols_out = [np.zeros(0, np.int64)]
    vals_out = [np.zeros(0, np.float32)]
    if isinstance(out, PanelSpgemmOut):
        # panels partition the column space: collecting every (bucket, panel)
        # block as COO and letting from_coo's stable sort order the entries
        # restores the single-matrix layout bitwise (DESIGN.md §8)
        _check_overflow(int(out.overflow), [int(out.overflow)], on_overflow)
        for i, bk in enumerate(plan.binning.buckets):
            if bk.n_rows == 0:
                continue
            for p in range(plan.n_panels):
                c_b = np.asarray(out.cols[i][p])[:bk.n_rows]
                v_b = np.asarray(out.vals[i][p])[:bk.n_rows]
                m = c_b != COL_SENTINEL
                counts = m.sum(axis=1)
                rows_out.append(np.repeat(bk.rows.astype(np.int64), counts))
                cols_out.append(c_b[m].astype(np.int64))
                vals_out.append(v_b[m])
        return CSR.from_coo(np.concatenate(rows_out),
                            np.concatenate(cols_out),
                            np.concatenate(vals_out).astype(np.float32),
                            (nrows, ncols), dedup=False, validate=False)
    if isinstance(out, DistSpgemmOut):
        _check_overflow(int(out.shard_overflow.sum()), out.shard_overflow,
                        on_overflow)
        for t, c_b, v_b in zip(plan.shard_tables, out.cols, out.vals):
            cap = t.capacity
            c_b = np.asarray(c_b).reshape(-1, cap)     # (S·rows_pb, cap)
            v_b = np.asarray(v_b).reshape(-1, cap)
            m = (c_b != COL_SENTINEL) & t.valid.reshape(-1)[:, None]
            counts = m.sum(axis=1)
            rows_out.append(np.repeat(
                t.table.reshape(-1).astype(np.int64), counts))
            cols_out.append(c_b[m].astype(np.int64))
            vals_out.append(v_b[m])
    else:
        _check_overflow(int(out.overflow), [int(out.overflow)], on_overflow)
        col = np.asarray(out.col)
        val = np.asarray(out.val)
        m = col != COL_SENTINEL
        counts = m.sum(axis=1)
        rows_out.append(np.repeat(np.arange(nrows, dtype=np.int64), counts))
        cols_out.append(col[m].astype(np.int64))
        vals_out.append(val[m])
    return CSR.from_coo(np.concatenate(rows_out), np.concatenate(cols_out),
                        np.concatenate(vals_out).astype(np.float32),
                        (nrows, ncols), dedup=False, validate=False)
