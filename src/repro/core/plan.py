"""Unified SpGEMM planner/executor with a signature-keyed plan cache.

This module subsumes the previously scattered plan state (``BinningPlan`` +
``AllocationPlan`` / ``BinnedAllocationPlan`` + ``DistSpGEMMPlan``) into ONE
pipeline (DESIGN.md §6) that runs the paper's whole point end to end:

  1. **sample → predict**: the binned, routed sampled-CR predictor
     (``predictor.proposed_predict_binned``, eq. 4) — not the global-pad one;
  2. **partition on predicted nnz**: output rows split into ``num_shards``
     contiguous ranges with ~equal *predicted* output nnz
     (``partition.balanced_contiguous`` — the paper's load-balance claim);
  3. **capacities per bucket per shard**: each degree bucket's output buffer
     is sized from the prediction restricted to the rows that bucket owns
     inside each shard (``predictor.shard_bucket_capacities``) — a hub row
     inflates only its own (tiny) bucket, never another shard's buffers;
  4. **execute through the binned routed kernels**: both the single-device
     and the shard_map executor run every bucket through
     ``spgemm.routed_spgemm_rows`` (ESC sort / dense-SPA dispatch, optional
     Pallas kernels via ``kernels.ops``) — the PR 1/2 wins reach pod scale.

**Plan cache.** Executors are built once per *plan key* — the static half of
the compile contract: matrix shapes, device-CSR capacities (pow2-padded so
same-family matrices share them), the ordered per-bucket
``(signature, population, capacity)`` tuples (``RowBucket.signature`` is the
``BinningPlan.signatures()`` contract from DESIGN.md §4), and the mesh
fingerprint.  Repeated SpGEMMs over same-shaped bucket sets — the serving
scenario — look up the same jitted executable and run with ZERO retraces
(``PlanCache.stats()["traces"]`` is pinned by ``tests/test_plan.py`` /
``tests/test_distributed.py``).

Public API::

    plan = plan_spgemm(a, b)                    # single device
    out  = execute(plan, a, b)                  # SpGEMMOut
    plan = plan_spgemm(a, b, mesh=mesh)         # distributed
    out  = execute(plan, a, b)                  # DistSpgemmOut
    c    = reassemble(plan, out, ncols=b.ncols) # host CSR
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.sparse.formats import CSR
from . import binning as binning_mod
from . import csr as csr_mod
from . import oracle
from . import partition as part_mod
from . import predictor as predictor_mod
from .csr import COL_SENTINEL, CSRDevice
from .spgemm import SpGEMMOut, pad_to_capacity, routed_spgemm_rows


# --------------------------------------------------------------------------- #
# Plan cache — session-level executor registry keyed on plan signatures.
# --------------------------------------------------------------------------- #
class PlanCache:
    """Maps plan keys to compiled (jitted) executors.

    ``hits``/``misses`` count executor lookups; ``traces`` counts actual
    executor retraces (the executor bodies bump it while being traced), so a
    cache-served SpGEMM over a same-shaped bucket set shows ``traces``
    unchanged — the zero-retrace serving contract.
    """

    def __init__(self) -> None:
        self._executors: dict = {}
        self.hits = 0
        self.misses = 0
        self.traces = 0

    def executor(self, key, build):
        """Get-or-build the executor for ``key`` (hashable plan key)."""
        if key in self._executors:
            self.hits += 1
        else:
            self.misses += 1
            self._executors[key] = build()
        return self._executors[key]

    def _note_trace(self) -> None:
        self.traces += 1

    def stats(self) -> dict:
        return dict(size=len(self._executors), hits=self.hits,
                    misses=self.misses, traces=self.traces)

    def clear(self) -> None:
        self._executors.clear()
        self.hits = self.misses = self.traces = 0


_DEFAULT_CACHE = PlanCache()


def plan_cache() -> PlanCache:
    """The session-level default plan cache."""
    return _DEFAULT_CACHE


# --------------------------------------------------------------------------- #
# Plan dataclasses
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class BucketShardTable:
    """One bucket's static shard execution table (distributed plans).

    ``table[s]`` lists the bucket rows shard ``s`` computes, padded to the
    bucket's max per-shard population ``rows_pb`` by repeating the shard's
    last owned row (or any bucket row when the shard owns none — padded
    outputs are masked off by ``valid`` at reassembly/overflow time).
    """

    table: np.ndarray       # (num_shards, rows_pb) int32
    valid: np.ndarray       # (num_shards, rows_pb) bool
    capacity: int           # static per-row output slots (max per-shard need)

    @property
    def rows_pb(self) -> int:
        return int(self.table.shape[1])


@dataclasses.dataclass(eq=False)   # identity compare; plans match via .key
class SpgemmPlan:
    """The unified plan: prediction + partition + capacities + executor key."""

    binning: binning_mod.BinningPlan
    alloc: predictor_mod.BinnedAllocationPlan
    structure: np.ndarray           # predicted nnz per output row (float64)
    flopr: np.ndarray               # FLOP per output row (int64)
    predicted_nnz: float
    compression_ratio: float
    sample_rows: np.ndarray
    shape_a: tuple[int, int]
    shape_b: tuple[int, int]
    cap_a: int                      # device-CSR col/val capacity (pow2-padded)
    cap_b: int
    safety: float
    use_kernel: bool
    # distributed-only (num_shards == 0 → single device)
    num_shards: int = 0
    axis: str = "data"
    partition: part_mod.Partition | None = None
    shard_tables: tuple[BucketShardTable, ...] = ()
    shard_capacities: np.ndarray | None = None  # (buckets, shards) per-shard need
    mesh: object = None             # not part of the key (see _mesh_key)
    _device_args: tuple | None = dataclasses.field(default=None, repr=False)
    # ((host_a, host_b), (ad, bd)) from planning — execute() on the planned
    # operands reuses the prediction pass's upload instead of a second H2D
    _planned_pair: tuple | None = dataclasses.field(default=None, repr=False)

    @property
    def distributed(self) -> bool:
        return self.num_shards > 0

    def device_args(self) -> tuple:
        """Executor row-table args (+ inverse perm for local plans), uploaded
        once per plan — the cache-served serving path pays pure dispatch."""
        if self._device_args is None:
            if self.distributed:
                args = tuple(jnp.asarray(t.table) for t in self.shard_tables)
            else:
                perm = jnp.asarray(
                    self.binning.inverse_perm().astype(np.int32))
                args = (perm,) + tuple(jnp.asarray(bk.rows)
                                       for bk in self.binning.buckets)
            self._device_args = args
        return self._device_args

    @property
    def key(self) -> tuple:
        """The static half of the compile contract (mesh fingerprint added
        at executor-lookup time, see :func:`_executor_key`)."""
        if self.distributed:
            buckets = tuple(
                (bk.signature, t.rows_pb, t.capacity)
                for bk, t in zip(self.binning.buckets, self.shard_tables))
        else:
            buckets = tuple(
                (bk.signature, bk.n_rows, int(cap))
                for bk, cap in zip(self.binning.buckets,
                                   self.alloc.bucket_capacities))
        return ("spgemm-plan", self.num_shards, self.axis, self.use_kernel,
                self.shape_a, self.shape_b, self.cap_a, self.cap_b,
                self.alloc.row_capacity, buckets)

    def shard_slots(self) -> int:
        """Output slots each shard allocates under this plan
        (Σ buckets rows_pb·capacity; SPMD — identical on every shard)."""
        if not self.distributed:
            return int(self.alloc.total_capacity)
        return int(sum(t.rows_pb * t.capacity for t in self.shard_tables))

    def to_device(self, m: CSR, which: str) -> CSRDevice:
        """Convert one operand at the plan's padded device capacity."""
        cap = self.cap_a if which == "a" else self.cap_b
        shape = self.shape_a if which == "a" else self.shape_b
        if m.shape != shape:
            raise ValueError(f"operand {which} shape {m.shape} != planned "
                             f"{shape}")
        if m.nnz > cap:
            raise ValueError(f"operand {which} nnz {m.nnz} exceeds planned "
                             f"device capacity {cap}")
        return csr_mod.to_device(m, capacity=cap)

    def stats(self) -> dict:
        out = dict(
            predicted_nnz=round(float(self.predicted_nnz), 1),
            compression_ratio=round(float(self.compression_ratio), 4),
            num_buckets=len(self.binning.buckets),
            lane_reduction=round(self.binning.lane_reduction, 3),
            route_rows=self.binning.route_rows(),
            bucket_capacities=list(self.alloc.bucket_capacities),
            total_capacity=int(self.alloc.total_capacity),
        )
        if self.distributed:
            out.update(
                num_shards=self.num_shards,
                imbalance=round(self.partition.imbalance, 4),
                shard_slots=self.shard_slots(),
                bucket_rows_per_shard=[t.rows_pb for t in self.shard_tables],
                shard_bucket_capacities=[t.capacity for t in self.shard_tables],
            )
        return out


class DistSpgemmOut(NamedTuple):
    """Distributed numeric-phase output: per-bucket stacked shard blocks."""

    cols: tuple        # per bucket: (num_shards, rows_pb, cap_b) int32
    vals: tuple        # per bucket: (num_shards, rows_pb, cap_b) float32
    row_nnz: tuple     # per bucket: (num_shards, rows_pb) int32 — true nnz
    shard_overflow: np.ndarray   # (num_shards,) int64 — valid rows only


# --------------------------------------------------------------------------- #
# Planning
# --------------------------------------------------------------------------- #
def _device_capacity(nnz: int) -> int:
    """pow2-padded device-CSR capacity: same-family matrices land on the
    same padded capacity, keeping the executor's traced shapes — and hence
    the plan cache — shared across them."""
    return binning_mod.ceil_pow2(max(8, int(nnz)))


def _mesh_key(mesh) -> tuple:
    if mesh is None:
        return ()
    return (tuple(mesh.axis_names),
            tuple(int(d.id) for d in np.asarray(mesh.devices).flat))


def _executor_key(plan: SpgemmPlan, mesh) -> tuple:
    return plan.key + (_mesh_key(mesh),)


def _build_shard_tables(binplan: binning_mod.BinningPlan,
                        partn: part_mod.Partition,
                        static_caps) -> tuple[BucketShardTable, ...]:
    bounds = np.asarray(partn.bounds)
    num_shards = partn.num_parts
    tables = []
    for bucket, cap in zip(binplan.buckets, static_caps):
        lo, hi = part_mod.shard_slices(bucket.rows, bounds)
        counts = hi - lo
        rows_pb = int(max(1, counts.max())) if counts.size else 1
        table = np.empty((num_shards, rows_pb), dtype=np.int32)
        valid = np.zeros((num_shards, rows_pb), dtype=bool)
        for s in range(num_shards):
            ids = bucket.rows[lo[s]:hi[s]]
            n = ids.size
            if n:
                table[s, :n] = ids
                table[s, n:] = ids[-1]
            else:
                # shard owns no rows of this bucket: pad with any bucket row
                # (stays inside the bucket's degree envelope; discarded)
                table[s, :] = bucket.rows[0]
            valid[s, :n] = True
        tables.append(BucketShardTable(table=table, valid=valid,
                                       capacity=int(cap)))
    return tuple(tables)


def plan_spgemm(a: CSR, b: CSR, *, mesh=None, num_shards: int | None = None,
                axis: str = "data", seed: int = 0, safety: float = 1.3,
                route: str = "auto", use_kernel: bool = False,
                sample_rows: np.ndarray | None = None,
                min_rows: int = binning_mod.DEFAULT_MIN_ROWS,
                deg_align: int = 1) -> SpgemmPlan:
    """Plan ``C = A·B``: sample → predict (binned, routed) → partition on
    predicted nnz → per-bucket(-per-shard) capacities.

    ``mesh``/``num_shards`` select distributed planning (``num_shards``
    alone plans without devices — useful for planning-time analysis; a mesh
    can then be supplied to :func:`execute`).  ``a``/``b`` are host ``CSR``;
    planning is a launch-time host step like ``core.partition``.
    """
    assert a.ncols == b.nrows, (a.shape, b.shape)
    binplan = binning_mod.build_plan(a, b, route=route, min_rows=min_rows,
                                     deg_align=deg_align)
    flopr, total_flop = oracle.flop_per_row(a, b)
    if sample_rows is None:
        sample_rows = (oracle.sample_rows(a.nrows, seed) if a.nrows
                       else np.zeros(0, dtype=np.int64))
    sample_rows = np.asarray(sample_rows, dtype=np.int64)

    cap_a = _device_capacity(a.nnz)
    cap_b = _device_capacity(b.nnz)
    devpair = None
    if total_flop > 0 and sample_rows.size:
        ad = csr_mod.to_device(a, capacity=cap_a)
        bd = csr_mod.to_device(b, capacity=cap_b)
        devpair = (ad, bd)
        pred = predictor_mod.proposed_predict_binned(
            ad, bd, jnp.asarray(sample_rows, dtype=jnp.int32), binplan,
            use_kernel=use_kernel, floprc=flopr)
        structure = np.asarray(pred.structure, dtype=np.float64)
        predicted_nnz = float(pred.nnz_total)
        cr = float(pred.compression_ratio)
        if not np.isfinite(structure).all() or cr <= 0:
            # sampled rows had no products (f* = 0): fall back to the
            # upper-bound structure — always safe, never over-allocates
            # past flopr by construction of the capacity rule.
            structure = flopr.astype(np.float64)
            predicted_nnz = float(total_flop)
            cr = 1.0
    else:
        structure = np.zeros(a.nrows, dtype=np.float64)
        predicted_nnz = 0.0
        cr = 1.0

    alloc = predictor_mod.BinnedAllocationPlan.from_prediction(
        binplan, structure, flopr, safety=safety)

    plan = SpgemmPlan(
        binning=binplan, alloc=alloc, structure=structure, flopr=flopr,
        predicted_nnz=predicted_nnz, compression_ratio=cr,
        sample_rows=sample_rows, shape_a=a.shape, shape_b=b.shape,
        cap_a=cap_a, cap_b=cap_b, safety=safety, use_kernel=use_kernel)
    if devpair is not None:
        plan._planned_pair = ((a, b), devpair)

    if mesh is not None or num_shards:
        shards = int(num_shards if num_shards else mesh.shape[axis])
        partn = part_mod.balanced_contiguous(structure, shards)
        caps_mat, static_caps = predictor_mod.shard_bucket_capacities(
            binplan, structure, flopr, partn.bounds, safety=safety)
        plan.num_shards = shards
        plan.axis = axis
        plan.partition = partn
        plan.shard_tables = _build_shard_tables(binplan, partn, static_caps)
        plan.shard_capacities = caps_mat
        plan.mesh = mesh
    return plan


# --------------------------------------------------------------------------- #
# Executors (cache-built, trace-counted)
# --------------------------------------------------------------------------- #
def _bucket_meta(bucket: binning_mod.RowBucket, cap: int) -> tuple:
    """Hashable static execution metadata for one bucket."""
    return (bucket.deg_a, bucket.deg_b, bucket.block_rows, bucket.route,
            bucket.tile_n, bucket.n_tiles, bucket.span, int(cap))


def _run_bucket(ad: CSRDevice, bd: CSRDevice, rows: jax.Array, meta: tuple,
                use_kernel: bool) -> SpGEMMOut:
    deg_a, deg_b, block_rows, route, tile_n, n_tiles, span, cap = meta
    return routed_spgemm_rows(
        ad, bd, rows, row_capacity=cap, deg_a=deg_a, deg_b=deg_b,
        block_rows=block_rows, route=route, tile_n=tile_n, n_tiles=n_tiles,
        span=span, use_kernel=use_kernel)


def _build_local_executor(metas: tuple, cap_out: int, use_kernel: bool,
                          cache: PlanCache):
    """Single-device executor: per-bucket routed passes + one concat/perm
    assembly — the :func:`repro.core.spgemm.spgemm_binned` dataflow inside
    one cached jit (row ids and the inverse permutation stay traced so the
    compiled program serves every same-keyed plan)."""

    @jax.jit
    def run(ad, bd, perm, *tables):
        cache._note_trace()
        parts_c, parts_v, parts_n = [], [], []
        overflow = jnp.int32(0)
        for meta, rows in zip(metas, tables):
            c, v, n, of = _run_bucket(ad, bd, rows, meta, use_kernel)
            c, v = pad_to_capacity(c, v, cap_out)
            parts_c.append(c)
            parts_v.append(v)
            parts_n.append(n.astype(jnp.int32))
            overflow = overflow + of.astype(jnp.int32)
        return SpGEMMOut(jnp.concatenate(parts_c, axis=0)[perm],
                         jnp.concatenate(parts_v, axis=0)[perm],
                         jnp.concatenate(parts_n, axis=0)[perm],
                         overflow)

    return run


def _build_dist_executor(metas: tuple, mesh, axis: str, use_kernel: bool,
                         cache: PlanCache):
    """shard_map executor: every shard runs every bucket's routed pass over
    its own row table — the binned/routed backend at pod scale.  A/B are
    replicated (index/value arrays broadcast once, as in the legacy path);
    only the row tables are sharded.  Per-shard overflow is derived host-
    side from the returned true ``row_nnz`` and the plan's valid masks."""

    def shard_fn(ad, bd, *tables):
        cache._note_trace()
        outs = []
        for meta, table in zip(metas, tables):
            c, v, n, _ = _run_bucket(ad, bd, table[0], meta, use_kernel)
            outs.extend([c[None], v[None], n.astype(jnp.int32)[None]])
        return tuple(outs)

    nb = len(metas)
    in_specs = (P(), P()) + (P(axis, None),) * nb
    out_specs = tuple(s for _ in range(nb)
                      for s in (P(axis, None, None), P(axis, None, None),
                                P(axis, None)))
    fn = shard_map(shard_fn, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    return jax.jit(fn)


def _coerce_pair(plan: SpgemmPlan, a, b) -> tuple[CSRDevice, CSRDevice]:
    def one(m, which: str, idx: int) -> CSRDevice:
        cap = plan.cap_a if which == "a" else plan.cap_b
        shape = plan.shape_a if which == "a" else plan.shape_b
        if isinstance(m, CSRDevice):
            # a pre-converted operand must sit at the plan's padded
            # capacity, or the cached executor would silently retrace per
            # distinct nnz (voiding the zero-retrace serving contract) —
            # or worse, compute a different matrix without complaint
            if m.shape != shape or m.capacity != cap:
                raise ValueError(
                    f"operand {which}: CSRDevice shape/capacity "
                    f"{m.shape}/{m.capacity} does not match the plan's "
                    f"{shape}/{cap} — convert with plan.to_device()")
            return m
        if plan._planned_pair is not None and m is plan._planned_pair[0][idx]:
            return plan._planned_pair[1][idx]
        return plan.to_device(m, which)

    return one(a, "a", 0), one(b, "b", 1)


def execute(plan: SpgemmPlan, a, b, *, mesh=None, cache: PlanCache | None = None):
    """Run the planned numeric phase.

    Single-device plans return a :class:`repro.core.spgemm.SpGEMMOut`;
    distributed plans return a :class:`DistSpgemmOut` (feed to
    :func:`reassemble`).  ``a``/``b`` may be host ``CSR`` (converted at the
    plan's padded capacities) or pre-converted ``CSRDevice``.  Executors are
    served from ``cache`` (default: the session cache) keyed on the plan's
    static signature — a second same-keyed plan reuses the compiled
    executable with zero retraces.
    """
    cache = cache if cache is not None else _DEFAULT_CACHE
    ad, bd = _coerce_pair(plan, a, b)
    if not plan.binning.buckets:
        cap = plan.alloc.row_capacity
        empty = SpGEMMOut(jnp.full((0, cap), COL_SENTINEL, jnp.int32),
                          jnp.zeros((0, cap), jnp.float32),
                          jnp.zeros((0,), jnp.int32), jnp.int32(0))
        if not plan.distributed:
            return empty
        return DistSpgemmOut((), (), (),
                             np.zeros(plan.num_shards, dtype=np.int64))

    if not plan.distributed:
        metas = tuple(_bucket_meta(bk, cap)
                      for bk, cap in zip(plan.binning.buckets,
                                         plan.alloc.bucket_capacities))
        run = cache.executor(
            _executor_key(plan, None),
            lambda: _build_local_executor(metas, plan.alloc.row_capacity,
                                          plan.use_kernel, cache))
        return run(ad, bd, *plan.device_args())

    mesh = mesh if mesh is not None else plan.mesh
    if mesh is None:
        raise ValueError("distributed plan needs a mesh (plan_spgemm(mesh=...)"
                         " or execute(..., mesh=...))")
    if int(mesh.shape[plan.axis]) != plan.num_shards:
        raise ValueError(
            f"plan was built for {plan.num_shards} shards but mesh axis "
            f"{plan.axis!r} has {int(mesh.shape[plan.axis])} devices — "
            "re-plan with this mesh")
    metas = tuple(_bucket_meta(bk, t.capacity)
                  for bk, t in zip(plan.binning.buckets, plan.shard_tables))
    run = cache.executor(
        _executor_key(plan, mesh),
        lambda: _build_dist_executor(metas, mesh, plan.axis,
                                     plan.use_kernel, cache))
    flat = run(ad, bd, *plan.device_args())
    cols, vals, nnzs = flat[0::3], flat[1::3], flat[2::3]
    overflow = np.zeros(plan.num_shards, dtype=np.int64)
    for t, n in zip(plan.shard_tables, nnzs):
        over = np.maximum(np.asarray(n, dtype=np.int64) - t.capacity, 0)
        overflow += np.where(t.valid, over, 0).sum(axis=1)
    return DistSpgemmOut(tuple(cols), tuple(vals), tuple(nnzs), overflow)


# --------------------------------------------------------------------------- #
# Reassembly (host-side; tests/examples)
# --------------------------------------------------------------------------- #
def _check_overflow(total: int, per_shard, on_overflow: str) -> None:
    if on_overflow not in ("raise", "ignore"):
        raise ValueError(f"on_overflow must be 'raise' or 'ignore', got "
                         f"{on_overflow!r}")
    if total and on_overflow == "raise":
        raise ValueError(f"SpGEMM overflow: {total} entries dropped "
                         f"(per shard: {list(np.asarray(per_shard))}); "
                         "re-plan with a higher safety factor or pass "
                         "on_overflow='ignore'")


def reassemble(plan: SpgemmPlan, out, ncols: int | None = None, *,
               on_overflow: str = "raise") -> CSR:
    """Stitch an :func:`execute` result back into one host CSR.

    Accepts a local ``SpGEMMOut`` or a distributed ``DistSpgemmOut``.
    Overflow (entries dropped for capacity) RAISES by default instead of
    silently truncating the result — pass ``on_overflow="ignore"`` to get
    the truncated matrix anyway.
    """
    ncols = int(ncols if ncols is not None else plan.shape_b[1])
    nrows = plan.shape_a[0]
    rows_out = [np.zeros(0, np.int64)]
    cols_out = [np.zeros(0, np.int64)]
    vals_out = [np.zeros(0, np.float32)]
    if isinstance(out, DistSpgemmOut):
        _check_overflow(int(out.shard_overflow.sum()), out.shard_overflow,
                        on_overflow)
        for t, c_b, v_b in zip(plan.shard_tables, out.cols, out.vals):
            cap = t.capacity
            c_b = np.asarray(c_b).reshape(-1, cap)     # (S·rows_pb, cap)
            v_b = np.asarray(v_b).reshape(-1, cap)
            m = (c_b != COL_SENTINEL) & t.valid.reshape(-1)[:, None]
            counts = m.sum(axis=1)
            rows_out.append(np.repeat(
                t.table.reshape(-1).astype(np.int64), counts))
            cols_out.append(c_b[m].astype(np.int64))
            vals_out.append(v_b[m])
    else:
        _check_overflow(int(out.overflow), [int(out.overflow)], on_overflow)
        col = np.asarray(out.col)
        val = np.asarray(out.val)
        m = col != COL_SENTINEL
        counts = m.sum(axis=1)
        rows_out.append(np.repeat(np.arange(nrows, dtype=np.int64), counts))
        cols_out.append(col[m].astype(np.int64))
        vals_out.append(val[m])
    return CSR.from_coo(np.concatenate(rows_out), np.concatenate(cols_out),
                        np.concatenate(vals_out).astype(np.float32),
                        (nrows, ncols), dedup=False)
