"""Load balance from predicted output structure (paper Section I / DESIGN §3).

The paper bins CPU rows by FLOP; at pod scale the analogous decision is which
*device shard* owns which row range.  Balancing on the **predicted nnz per
row** (not FLOP) equalizes accumulation work and output bytes — FLOP-balanced
partitions are skewed by exactly the compression ratio the paper predicts.

Host-side (numpy): partitioning is a launch-time decision feeding shard_map.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Partition:
    bounds: np.ndarray        # int64 (num_parts+1,) row-range boundaries
    part_weight: np.ndarray   # float64 (num_parts,)
    imbalance: float          # max part weight / mean part weight

    @property
    def num_parts(self) -> int:
        return len(self.part_weight)


def balanced_contiguous(weights: np.ndarray, num_parts: int) -> Partition:
    """Contiguous row ranges with ~equal total weight (prefix-split)."""
    w = np.asarray(weights, dtype=np.float64)
    cum = np.cumsum(w)
    total = cum[-1] if cum.size else 0.0
    targets = total * (np.arange(1, num_parts) / num_parts)
    inner = np.searchsorted(cum, targets, side="left")
    bounds = np.concatenate([[0], inner, [w.size]]).astype(np.int64)
    bounds = np.maximum.accumulate(bounds)  # monotone even for degenerate w
    pw = np.add.reduceat(w, bounds[:-1]) if w.size else np.zeros(num_parts)
    pw = pw * (np.diff(bounds) > 0)  # empty parts weigh nothing
    mean = total / num_parts if num_parts else 1.0
    imb = float(pw.max() / mean) if total > 0 else 1.0
    return Partition(bounds=bounds, part_weight=pw, imbalance=imb)


# --------------------------------------------------------------------------- #
# Column panels (DESIGN.md §8): the output column space of C = A·B is split
# into contiguous panels of B columns so the distributed numeric phase can
# lay B out along a second (or folded) mesh axis instead of replicating it.
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class PanelPartition:
    """Contiguous column panels of B: ``[edges[p], edges[p+1])`` per panel."""

    edges: np.ndarray         # int64 (n_panels+1,) column boundaries, 0..ncols
    panel_nnz: np.ndarray     # int64 (n_panels,) B entries per panel
    quantized: bool = False   # edges snapped to the pow2 grid (cache-stable)

    @property
    def n_panels(self) -> int:
        return int(self.edges.size - 1)

    @property
    def key(self) -> tuple:
        """Hashable static half — part of the panel plan-cache key."""
        return (self.n_panels, self.quantized,
                tuple(int(e) for e in self.edges))

    def panel_of(self, cols: np.ndarray) -> np.ndarray:
        """Column id → owning panel index."""
        return np.searchsorted(self.edges, np.asarray(cols), side="right") - 1


def panel_grid(ncols: int, n_panels: int) -> int:
    """The pow2 edge grid quantized panel boundaries snap to.

    Coarse enough that same-family different-seed edge jitter collapses onto
    one grid point (cache-stable keys), fine enough (≤ ~1/8 of a panel, the
    snap is half a grid step) that snapping cannot materially unbalance the
    panels."""
    from .binning import floor_pow2
    return max(1, floor_pow2(max(1, ncols // (4 * max(1, n_panels)))))


def quantize_panel_edges(edges: np.ndarray, ncols: int) -> np.ndarray:
    """Snap interior panel edges to the pow2 grid (endpoints fixed).

    Two edge lists collide after quantization **iff** every interior edge
    pair falls in the same grid band (nearest grid point) — the panel half
    of the plan-cache quantization contract (``tests/test_panels.py``).
    Monotonicity is preserved; degenerate inputs may yield empty panels,
    which execute as no-ops."""
    edges = np.asarray(edges, dtype=np.int64)
    g = panel_grid(ncols, edges.size - 1)
    inner = np.clip((edges[1:-1] + g // 2) // g * g, 0, ncols)
    out = np.concatenate([edges[:1], inner, edges[-1:]])
    return np.maximum.accumulate(out)


def column_panels(b, n_panels: int, *, quantize: bool = False
                  ) -> PanelPartition:
    """Split B's column space into ``n_panels`` contiguous panels with
    ~equal B nnz per panel (prefix-split over per-column counts, the column
    analogue of :func:`balanced_contiguous`).

    ``quantize`` snaps the interior edges to the pow2 grid so same-family
    different-seed matrices land on identical panel keys (the §7 plan-cache
    quantization knob, extended to panels)."""
    if int(n_panels) < 1:
        from .errors import PlanMismatchError
        raise PlanMismatchError(
            f"column_panels needs n_panels >= 1, got {n_panels}",
            observed=int(n_panels), planned=1)
    ncols = int(b.shape[1])
    counts = np.bincount(np.asarray(b.col, dtype=np.int64),
                         minlength=max(1, ncols)).astype(np.float64)
    cum = np.cumsum(counts[:ncols]) if ncols else np.zeros(0)
    total = cum[-1] if cum.size else 0.0
    targets = total * (np.arange(1, n_panels) / n_panels)
    # edge e means panel boundary BEFORE column e: prefix nnz of cols < e
    inner = np.searchsorted(cum, targets, side="left") + 1 if ncols else \
        np.zeros(n_panels - 1, dtype=np.int64)
    edges = np.concatenate([[0], np.minimum(inner, ncols),
                            [ncols]]).astype(np.int64)
    edges = np.maximum.accumulate(edges)
    if quantize:
        edges = quantize_panel_edges(edges, ncols)
    pnnz = np.zeros(n_panels, dtype=np.int64)
    for p in range(n_panels):
        lo, hi = int(edges[p]), int(edges[p + 1])
        pnnz[p] = int(cum[hi - 1] - (cum[lo - 1] if lo else 0.0)) if hi > lo \
            else 0
    return PanelPartition(edges=edges, panel_nnz=pnnz,
                          quantized=bool(quantize))


def static_row_assignment(part: Partition, rows_per_part: int) -> np.ndarray:
    """(num_parts, rows_per_part) row-id table, padded by repeating the last
    row of each range — the static-shape input shard_map needs."""
    out = np.zeros((part.num_parts, rows_per_part), dtype=np.int32)
    for i in range(part.num_parts):
        lo, hi = int(part.bounds[i]), int(part.bounds[i + 1])
        n = hi - lo
        if n == 0:
            out[i] = 0
            continue
        ids = np.arange(lo, hi, dtype=np.int32)
        if n >= rows_per_part:
            out[i] = ids[:rows_per_part]
        else:
            out[i, :n] = ids
            out[i, n:] = ids[-1]
    return out


def shard_slices(sorted_rows: np.ndarray,
                 bounds: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per-part ``[lo, hi)`` index ranges of an ascending row-id list under
    contiguous row-range ``bounds`` (len num_parts+1).

    ``sorted_rows[lo[s]:hi[s]]`` are exactly the listed rows owned by part
    ``s`` — the bucket∩shard intersection the unified planner (``core.plan``)
    uses to build per-bucket shard tables.
    """
    r = np.asarray(sorted_rows)
    b = np.asarray(bounds)
    lo = np.searchsorted(r, b[:-1], side="left")
    hi = np.searchsorted(r, b[1:], side="left")
    return lo, hi


def binned_cost_weights(plan) -> np.ndarray:
    """Per-row cost model under binned execution (``core.binning``): a row
    costs its bucket's padded buffer width, not its own degree — the buffer
    is what the device actually streams.  Feed to ``balanced_contiguous`` to
    balance shards for the binned pipeline."""
    w = np.zeros(plan.nrows, dtype=np.float64)
    for b in plan.buckets:
        w[b.rows] = float(b.width)
    return w


def straggler_report(part_flop: Partition, part_pred: Partition) -> dict:
    """Compare FLOP-balanced vs predicted-NNZ-balanced imbalance (the paper's
    load-balance claim, measured as the straggler factor a pod would see)."""
    return dict(
        flop_balanced_imbalance=part_flop.imbalance,
        predicted_nnz_balanced_imbalance=part_pred.imbalance,
        straggler_speedup=part_flop.imbalance / max(part_pred.imbalance, 1e-9),
    )
