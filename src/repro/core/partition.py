"""Load balance from predicted output structure (paper Section I / DESIGN §3).

The paper bins CPU rows by FLOP; at pod scale the analogous decision is which
*device shard* owns which row range.  Balancing on the **predicted nnz per
row** (not FLOP) equalizes accumulation work and output bytes — FLOP-balanced
partitions are skewed by exactly the compression ratio the paper predicts.

Host-side (numpy): partitioning is a launch-time decision feeding shard_map.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Partition:
    bounds: np.ndarray        # int64 (num_parts+1,) row-range boundaries
    part_weight: np.ndarray   # float64 (num_parts,)
    imbalance: float          # max part weight / mean part weight

    @property
    def num_parts(self) -> int:
        return len(self.part_weight)


def balanced_contiguous(weights: np.ndarray, num_parts: int) -> Partition:
    """Contiguous row ranges with ~equal total weight (prefix-split)."""
    w = np.asarray(weights, dtype=np.float64)
    cum = np.cumsum(w)
    total = cum[-1] if cum.size else 0.0
    targets = total * (np.arange(1, num_parts) / num_parts)
    inner = np.searchsorted(cum, targets, side="left")
    bounds = np.concatenate([[0], inner, [w.size]]).astype(np.int64)
    bounds = np.maximum.accumulate(bounds)  # monotone even for degenerate w
    pw = np.add.reduceat(w, bounds[:-1]) if w.size else np.zeros(num_parts)
    pw = pw * (np.diff(bounds) > 0)  # empty parts weigh nothing
    mean = total / num_parts if num_parts else 1.0
    imb = float(pw.max() / mean) if total > 0 else 1.0
    return Partition(bounds=bounds, part_weight=pw, imbalance=imb)


def static_row_assignment(part: Partition, rows_per_part: int) -> np.ndarray:
    """(num_parts, rows_per_part) row-id table, padded by repeating the last
    row of each range — the static-shape input shard_map needs."""
    out = np.zeros((part.num_parts, rows_per_part), dtype=np.int32)
    for i in range(part.num_parts):
        lo, hi = int(part.bounds[i]), int(part.bounds[i + 1])
        n = hi - lo
        if n == 0:
            out[i] = 0
            continue
        ids = np.arange(lo, hi, dtype=np.int32)
        if n >= rows_per_part:
            out[i] = ids[:rows_per_part]
        else:
            out[i, :n] = ids
            out[i, n:] = ids[-1]
    return out


def shard_slices(sorted_rows: np.ndarray,
                 bounds: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per-part ``[lo, hi)`` index ranges of an ascending row-id list under
    contiguous row-range ``bounds`` (len num_parts+1).

    ``sorted_rows[lo[s]:hi[s]]`` are exactly the listed rows owned by part
    ``s`` — the bucket∩shard intersection the unified planner (``core.plan``)
    uses to build per-bucket shard tables.
    """
    r = np.asarray(sorted_rows)
    b = np.asarray(bounds)
    lo = np.searchsorted(r, b[:-1], side="left")
    hi = np.searchsorted(r, b[1:], side="left")
    return lo, hi


def binned_cost_weights(plan) -> np.ndarray:
    """Per-row cost model under binned execution (``core.binning``): a row
    costs its bucket's padded buffer width, not its own degree — the buffer
    is what the device actually streams.  Feed to ``balanced_contiguous`` to
    balance shards for the binned pipeline."""
    w = np.zeros(plan.nrows, dtype=np.float64)
    for b in plan.buckets:
        w[b.rows] = float(b.width)
    return w


def straggler_report(part_flop: Partition, part_pred: Partition) -> dict:
    """Compare FLOP-balanced vs predicted-NNZ-balanced imbalance (the paper's
    load-balance claim, measured as the straggler factor a pod would see)."""
    return dict(
        flop_balanced_imbalance=part_flop.imbalance,
        predicted_nnz_balanced_imbalance=part_pred.imbalance,
        straggler_speedup=part_flop.imbalance / max(part_pred.imbalance, 1e-9),
    )
