"""Training driver: config → data → sharded train loop → checkpoints.

Runs on whatever devices exist (1 CPU here, a pod mesh in production — the
mesh is data×model over available devices).  Fault tolerance in the loop:
resume-from-latest on start, periodic atomic checkpoints, preemption-safe
(SIGTERM triggers a final checkpoint before exit).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --steps 50 \
      --smoke --batch 8 --seq 128 --ckpt-dir /tmp/run1
"""
from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import transformer as tmod
from repro.models.schema import init_params
from repro.train import optimizer as opt_mod
from repro.train.train_loop import make_train_step
from repro.ckpt import checkpoint as ckpt_mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    schema = tmod.build_schema(cfg, mesh_model=1)
    params = init_params(schema, jax.random.PRNGKey(args.seed),
                         jnp.dtype(cfg.dtype))
    opt_cfg = opt_mod.AdamWConfig(lr_peak=args.lr, warmup_steps=args.warmup,
                                  total_steps=args.steps,
                                  state_dtype=cfg.opt_state_dtype)
    opt_state = opt_mod.init_state(opt_cfg, params)

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                                  global_batch=args.batch, seed=args.seed))

    start_step = 0
    if args.ckpt_dir and ckpt_mod.latest_step(args.ckpt_dir) is not None:
        (params, opt_state), extra, start_step = ckpt_mod.restore(
            args.ckpt_dir, (params, opt_state))
        print(f"[train] resumed from step {start_step}", flush=True)

    step_fn = jax.jit(make_train_step(cfg, opt_cfg, accum=args.accum))

    stop = {"now": False}
    if args.ckpt_dir:
        def _sig(_s, _f):
            stop["now"] = True
        signal.signal(signal.SIGTERM, _sig)

    def make_batch(i):
        b = data.batch(i)
        out = {"tokens": jnp.asarray(b["tokens"]),
               "labels": jnp.asarray(b["labels"]),
               "positions": jnp.asarray(b["positions"])}
        if cfg.mrope_sections:
            out["positions"] = jnp.broadcast_to(out["positions"][None],
                                                (3,) + b["positions"].shape)
        if cfg.frontend == "vision_stub":
            rng = np.random.default_rng(i)
            out["patch_embeds"] = jnp.asarray(
                rng.standard_normal((args.batch, 8, cfg.d_model)),
                jnp.dtype(cfg.dtype))
        if cfg.frontend == "audio_stub":
            rng = np.random.default_rng(i)
            out["frame_embeds"] = jnp.asarray(
                rng.standard_normal(
                    (args.batch, cfg.encoder_seq_len, cfg.d_model)),
                jnp.dtype(cfg.dtype))
        return out

    losses = []
    t0 = time.time()
    for i in range(start_step, args.steps):
        params, opt_state, metrics = step_fn(params, opt_state, make_batch(i))
        losses.append(float(metrics["loss"]))
        if (i + 1) % args.log_every == 0 or i == args.steps - 1:
            print(f"[train] step {i+1:5d} loss {losses[-1]:.4f} "
                  f"ce {float(metrics['ce']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0)/max(i+1-start_step,1):.2f}s/step)",
                  flush=True)
        if args.ckpt_dir and ((i + 1) % args.ckpt_every == 0 or stop["now"]
                              or i == args.steps - 1):
            ckpt_mod.save(args.ckpt_dir, i + 1, (params, opt_state),
                          extra={"seed": args.seed})
            if stop["now"]:
                print("[train] preemption checkpoint written; exiting",
                      flush=True)
                sys.exit(0)
    first, last = losses[0], np.mean(losses[-5:])
    print(f"[train] done: first loss {first:.4f} → last(avg5) {last:.4f}")
    return first, last


if __name__ == "__main__":
    main()
