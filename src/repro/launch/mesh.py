"""Production meshes.  A FUNCTION, not a module constant — importing this
module never touches jax device state (required for the smoke-test/dry-run
split: tests see 1 device, the dry-run sees 512 placeholders)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(devices: int = 4):
    """Tiny mesh for CI-class integration tests (data×model square-ish)."""
    d = max(1, devices // 2)
    m = max(1, devices // d)
    return jax.make_mesh((d, m), ("data", "model"))


def mesh_chip_count(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
