"""Input specs (ShapeDtypeStruct stand-ins) and shardings per (arch × shape).

The four assigned shape cells; ``decode_*``/``long_*`` lower ``serve_step``
(one new token against a seq_len KV cache), ``train_4k`` lowers
``train_step``, ``prefill_32k`` lowers the full-sequence forward.
long_500k runs only for the sub-quadratic archs (DESIGN §6).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

SHAPES: dict[str, dict] = {
    "train_4k":    dict(kind="train",   seq=4_096,   batch=256),
    "prefill_32k": dict(kind="prefill", seq=32_768,  batch=32),
    "decode_32k":  dict(kind="decode",  seq=32_768,  batch=128),
    "long_500k":   dict(kind="decode",  seq=524_288, batch=1),
}

# long-context decode needs sub-quadratic state (SSM / hybrid-with-window)
LONG_CONTEXT_ARCHS = {"xlstm-125m", "zamba2-7b"}

VISION_PATCHES = 256          # vlm stub: patches prepended to the sequence


def cell_is_live(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True


def live_cells(archs: list[str]) -> list[tuple[str, str]]:
    return [(a, s) for a in archs for s in SHAPES if cell_is_live(a, s)]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_structs(cfg: ModelConfig, shape_name: str) -> dict[str, Any]:
    """ShapeDtypeStructs for the model inputs of a train/prefill cell."""
    sh = SHAPES[shape_name]
    b, s = sh["batch"], sh["seq"]
    dt = jnp.dtype(cfg.dtype)
    batch: dict[str, Any] = {"tokens": _sds((b, s), jnp.int32)}
    if cfg.mrope_sections:
        batch["positions"] = _sds((3, b, s), jnp.int32)
    else:
        batch["positions"] = _sds((b, s), jnp.int32)
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = _sds((b, VISION_PATCHES, cfg.d_model), dt)
    if cfg.frontend == "audio_stub":
        batch["frame_embeds"] = _sds((b, cfg.encoder_seq_len, cfg.d_model), dt)
    if sh["kind"] == "train":
        batch["labels"] = _sds((b, s), jnp.int32)
    return batch


def _batch_axes(cfg: ModelConfig, batch: int, multi_pod: bool):
    """Longest divisible prefix of the batch-shardable mesh axes."""
    axes = [("pod", 2)] if multi_pod else []
    axes.append(("data", 16))
    if not cfg.tensor_parallel:
        axes.append(("model", 16))
    chosen, prod = [], 1
    for name, size in axes:
        if batch % (prod * size) == 0:
            chosen.append(name)
            prod *= size
    if not chosen:
        return None
    return chosen[0] if len(chosen) == 1 else tuple(chosen)


def batch_pspecs(cfg: ModelConfig, shape_name: str, multi_pod: bool) -> dict:
    sh = SHAPES[shape_name]
    dshard = _batch_axes(cfg, sh["batch"], multi_pod)
    out = {"tokens": P(dshard, None)}
    out["positions"] = P(None, dshard, None) if cfg.mrope_sections else P(dshard, None)
    if cfg.frontend == "vision_stub":
        out["patch_embeds"] = P(dshard, None, None)
    if cfg.frontend == "audio_stub":
        out["frame_embeds"] = P(dshard, None, None)
    if sh["kind"] == "train":
        out["labels"] = P(dshard, None)
    return out


def decode_structs(cfg: ModelConfig, shape_name: str, mesh_model: int = 16):
    """(tokens, cur_len, cache, enc_out?) structs for a decode cell."""
    from repro.models import transformer as tmod
    sh = SHAPES[shape_name]
    b, s = sh["batch"], sh["seq"]
    tokens = _sds((b, 1), jnp.int32)
    cur_len = _sds((), jnp.int32)
    cache = jax.eval_shape(lambda: tmod.init_cache(cfg, b, s, mesh_model))
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _sds((b, cfg.encoder_seq_len, cfg.d_model),
                       jnp.dtype(cfg.dtype))
    return tokens, cur_len, cache, enc_out


def decode_pspecs(cfg: ModelConfig, shape_name: str, multi_pod: bool,
                  mesh_model: int = 16):
    from repro.models.sharding import cache_spec_tree
    sh = SHAPES[shape_name]
    dsize = 32 if multi_pod else 16
    data = ("pod", "data") if multi_pod else "data"
    dshard = data if sh["batch"] % dsize == 0 else None
    cache_specs = cache_spec_tree(cfg, mesh_model, multi_pod)
    if dshard is None:  # long_500k batch=1: replicate the batch axis
        cache_specs = jax.tree_util.tree_map(
            lambda p: P(*[None if ax in ("data", ("pod", "data")) else ax
                          for ax in p]), cache_specs,
            is_leaf=lambda x: isinstance(x, P))
    tokens_spec = P(dshard, None)
    enc_spec = P(dshard, None, None) if cfg.is_encoder_decoder else None
    return tokens_spec, P(), cache_specs, enc_spec
