import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each live cell this script jits the real step function (train_step /
forward / decode_step) with the production in/out shardings, lowers it
against ShapeDtypeStruct inputs (no allocation), compiles for the
single-pod (16,16) and multi-pod (2,16,16) meshes, and records
memory_analysis / cost_analysis / the parsed collective schedule to
artifacts/dryrun/<arch>__<shape>__<mesh>.json — the roofline inputs.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch xlstm-125m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi    # 512-chip only
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import get_config, registry
from repro.launch.mesh import make_production_mesh, mesh_chip_count
from repro.launch import specs as specs_mod
from repro.models import transformer as tmod
from repro.models.schema import abstract_params
from repro.models.sharding import make_rules, specs_from_schema
from repro.train import optimizer as opt_mod
from repro.train.train_loop import make_train_step
from repro.roofline import analysis as roof
from repro.roofline import hlo_cost

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")


def _shard(mesh, tree_specs):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))


def _mem_analysis(compiled):
    try:
        ma = compiled.memory_analysis()
        return dict(
            argument_size=getattr(ma, "argument_size_in_bytes", None),
            output_size=getattr(ma, "output_size_in_bytes", None),
            temp_size=getattr(ma, "temp_size_in_bytes", None),
            generated_code_size=getattr(ma, "generated_code_size_in_bytes", None),
        )
    except Exception as e:  # CPU backend may not implement it
        return {"error": str(e)}


def _cost_analysis(compiled):
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and
                (k in ("flops", "bytes accessed") or k.startswith("bytes accessed"))}
    except Exception as e:
        return {"error": str(e)}


def lower_cell(arch: str, shape: str, multi_pod: bool, *, keep_hlo: bool = False):
    import dataclasses
    cfg = get_config(arch)
    # the de-TP recipe only pays when the batch shards over BOTH mesh axes;
    # small-batch cells of sub-1B archs fall back to TP (EXPERIMENTS §Perf
    # iteration 5 — blanket de-TP replicated compute 16× on whisper prefill)
    if not cfg.tensor_parallel:
        full = 512 if multi_pod else 256
        if specs_mod.SHAPES[shape]["batch"] % full != 0:
            cfg = dataclasses.replace(cfg, tensor_parallel=True)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chip_count(mesh)
    mesh_model = 16
    kind = specs_mod.SHAPES[shape]["kind"]
    dt = jnp.dtype(cfg.dtype)

    schema = tmod.build_schema(cfg, mesh_model=mesh_model)
    rules = make_rules(cfg, mesh_model=mesh_model, multi_pod=multi_pod)
    pspecs = specs_from_schema(schema, rules)
    params_abs = abstract_params(schema, dtype=dt)
    params_sh = _shard(mesh, pspecs)

    t0 = time.time()
    if kind == "train":
        opt_cfg = opt_mod.AdamWConfig(state_dtype=cfg.opt_state_dtype)
        opt_abs = jax.eval_shape(
            lambda p: opt_mod.init_state(opt_cfg, p), params_abs)
        # ZeRO: optimizer state additionally shards `embed` over data(+pod)
        zero_rules = dict(rules)
        zero_rules["embed"] = ("pod", "data") if multi_pod else ("data",)
        opt_specs = opt_mod.AdamState(
            P(), specs_from_schema(schema, zero_rules),
            specs_from_schema(schema, zero_rules))
        opt_sh = _shard(mesh, opt_specs)
        batch_abs = specs_mod.batch_structs(cfg, shape)
        batch_sh = _shard(mesh, specs_mod.batch_pspecs(cfg, shape, multi_pod))
        accum = int(os.environ.get("REPRO_TRAIN_ACCUM", "1"))
        step = make_train_step(cfg, opt_cfg, accum=accum)
        jitted = jax.jit(step,
                         in_shardings=(params_sh, opt_sh, batch_sh),
                         out_shardings=(params_sh, opt_sh, None))
        with mesh:
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
        tokens = specs_mod.SHAPES[shape]["batch"] * specs_mod.SHAPES[shape]["seq"]
        mflops = roof.model_flops_train(cfg, tokens)
    elif kind == "prefill":
        batch_abs = specs_mod.batch_structs(cfg, shape)
        batch_sh = _shard(mesh, specs_mod.batch_pspecs(cfg, shape, multi_pod))

        def fwd(params, batch):
            logits, aux, _ = tmod.forward(params, cfg, batch)
            return logits

        jitted = jax.jit(fwd, in_shardings=(params_sh, batch_sh),
                         out_shardings=None)
        with mesh:
            lowered = jitted.lower(params_abs, batch_abs)
        tokens = specs_mod.SHAPES[shape]["batch"] * specs_mod.SHAPES[shape]["seq"]
        mflops = roof.model_flops_prefill(cfg, tokens)
    else:  # decode
        tokens_abs, len_abs, cache_abs, enc_abs = specs_mod.decode_structs(cfg, shape)
        tok_spec, len_spec, cache_specs, enc_spec = specs_mod.decode_pspecs(
            cfg, shape, multi_pod)
        cache_sh = _shard(mesh, cache_specs)

        if cfg.is_encoder_decoder:
            def serve_step(params, cache, tokens, cur_len, enc_out):
                return tmod.decode_step(params, cfg, tokens, cache, cur_len,
                                        enc_out=enc_out)
            jitted = jax.jit(serve_step, in_shardings=(
                params_sh, cache_sh, NamedSharding(mesh, tok_spec),
                NamedSharding(mesh, len_spec), NamedSharding(mesh, enc_spec)),
                out_shardings=(None, cache_sh))
            with mesh:
                lowered = jitted.lower(params_abs, cache_abs, tokens_abs,
                                       len_abs, enc_abs)
        else:
            def serve_step(params, cache, tokens, cur_len):
                return tmod.decode_step(params, cfg, tokens, cache, cur_len)
            jitted = jax.jit(serve_step, in_shardings=(
                params_sh, cache_sh, NamedSharding(mesh, tok_spec),
                NamedSharding(mesh, len_spec)),
                out_shardings=(None, cache_sh))
            with mesh:
                lowered = jitted.lower(params_abs, cache_abs, tokens_abs, len_abs)
        mflops = roof.model_flops_decode(cfg, specs_mod.SHAPES[shape]["batch"])
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = _mem_analysis(compiled)
    cost = {k: v for k, v in _cost_analysis(compiled).items()
            if k in ("flops", "bytes accessed", "error")}
    hlo = compiled.as_text()
    # trip-count-aware model (xla cost_analysis counts scan bodies once)
    parsed = hlo_cost.analyze(hlo)
    rl = roof.Roofline.build(parsed["flops"], parsed["bytes"],
                             parsed["collectives"], mflops, chips)
    rec = dict(arch=arch, shape=shape, mesh="multi" if multi_pod else "single",
               chips=chips, kind=kind, lower_s=t_lower, compile_s=t_compile,
               memory_analysis=mem, cost_analysis_raw=cost,
               hlo_parsed=dict(flops=parsed["flops"], bytes=parsed["bytes"],
                               collectives=parsed["collectives"]),
               roofline=rl.to_dict(), hlo_bytes=len(hlo))
    if keep_hlo:
        rec["hlo_path"] = _save_hlo(arch, shape, multi_pod, hlo)
    print(f"[dryrun] {arch} × {shape} × {'multi' if multi_pod else 'single'}: "
          f"compile {t_compile:.1f}s  flops/chip {parsed['flops']:.3e}  "
          f"coll/chip {sum(parsed['collectives'].values()):.3e}B  "
          f"bottleneck {rl.bottleneck}", flush=True)
    print("  memory_analysis:", mem, flush=True)
    print("  cost_analysis:", {k: f"{v:.3e}" for k, v in cost.items()
                               if isinstance(v, float)}, flush=True)
    return rec


def _save_hlo(arch, shape, multi_pod, hlo):
    out_dir = os.environ.get("REPRO_HLO_DIR", os.path.abspath(ART_DIR))
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir,
                        f"{arch}__{shape}__{'multi' if multi_pod else 'single'}.hlo")
    with open(path, "w") as f:
        f.write(hlo)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--keep-hlo", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(registry().keys())
    shapes = [args.shape] if args.shape else list(specs_mod.SHAPES.keys())
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    art = os.path.abspath(ART_DIR)
    os.makedirs(art, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            if not specs_mod.cell_is_live(arch, shape):
                print(f"[dryrun] skip {arch} × {shape} (DESIGN §6)", flush=True)
                continue
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                out = os.path.join(art, tag + ".json")
                if os.path.exists(out):
                    print(f"[dryrun] cached {tag}", flush=True)
                    continue
                try:
                    rec = lower_cell(arch, shape, mp, keep_hlo=args.keep_hlo)
                    with open(out + ".tmp", "w") as f:
                        json.dump(rec, f, indent=1)
                    os.replace(out + ".tmp", out)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((tag, str(e)))
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:")
        for t, e in failures:
            print("  ", t, e[:200])
        raise SystemExit(1)
    print("[dryrun] ALL CELLS COMPILED", flush=True)


if __name__ == "__main__":
    main()
