"""Serving driver: load a checkpoint (or init), serve batched requests.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b --smoke \
      --batch 4 --prompt-len 8 --gen 16 [--ckpt-dir /tmp/run1]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, get_smoke_config
from repro.models import transformer as tmod
from repro.models.schema import init_params
from repro.serve import engine
from repro.ckpt import checkpoint as ckpt_mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_params(tmod.build_schema(cfg, 1), jax.random.PRNGKey(0),
                         jnp.dtype(cfg.dtype))
    if args.ckpt_dir and ckpt_mod.latest_step(args.ckpt_dir) is not None:
        # checkpoints store (params, opt_state); restore params only
        import jax.tree_util as jtu
        opt_like = None
        try:
            from repro.train import optimizer as opt_mod
            opt_like = jax.eval_shape(
                lambda p: opt_mod.init_state(opt_mod.AdamWConfig(), p), params)
            (params, _), _, step = ckpt_mod.restore(
                args.ckpt_dir, (params, opt_like))
            print(f"[serve] restored step {step}")
        except AssertionError:
            params, _, step = ckpt_mod.restore(args.ckpt_dir, params)
            print(f"[serve] restored (params-only) step {step}")

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    fe = None
    if cfg.is_encoder_decoder:
        fe = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.encoder_seq_len, cfg.d_model)), jnp.float32)
    sess = engine.start_session(cfg, params, args.batch,
                                args.prompt_len + args.gen + 1,
                                frame_embeds=fe)
    t0 = time.time()
    toks = engine.generate(sess, prompts, args.gen,
                           temperature=args.temperature, seed=args.seed)
    dt = time.time() - t0
    print("[serve] generated:\n", np.asarray(toks))
    print(f"[serve] {args.batch * args.gen} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s batched)")


if __name__ == "__main__":
    main()
