"""AdamW with ZeRO-style sharded state, cosine schedule, global-norm clip.

Self-contained (no optax in this environment).  Features needed at pod scale:
  * optimizer state dtype knob (``bfloat16`` m/v for the ≥100B archs — halves
    the dominant per-chip memory term; updates computed in fp32 regardless),
  * state PartitionSpecs derived from the param specs (state shards like the
    param, and additionally over `data` when the config runs FSDP → ZeRO),
  * global-norm clipping with the norm computed once (one all-reduce under
    pjit), and
  * a pure functional API: (grads, state, params) → (new_params, new_state).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 200
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to lr_min."""
    warm = cfg.lr_peak * (step + 1) / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps) /
                 max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(cfg: AdamWConfig, params) -> AdamState:
    dt = jnp.dtype(cfg.state_dtype)
    z = lambda p: jnp.zeros(p.shape, dt)
    return AdamState(jnp.zeros((), jnp.int32),
                     jax.tree_util.tree_map(z, params),
                     jax.tree_util.tree_map(z, params))


def state_specs(param_specs) -> AdamState:
    """State shards exactly like its param."""
    from jax.sharding import PartitionSpec as P
    return AdamState(P(), param_specs, param_specs)


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree_util.tree_leaves(tree))
    return jnp.sqrt(sq)


def apply_updates(cfg: AdamWConfig, grads, state: AdamState, params):
    """Returns (new_params, new_state, metrics dict)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, state.step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    sd = jnp.dtype(cfg.state_dtype)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m32.astype(sd), v32.astype(sd)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.mu)
    flat_v = jax.tree_util.tree_leaves(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamState(step, new_m, new_v), metrics
