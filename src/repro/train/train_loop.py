"""Loss, train_step factory, and the host-side training loop.

The train_step is a pure function (params, opt_state, batch) → (params,
opt_state, metrics) suitable for ``jax.jit`` with explicit in/out shardings —
the same function the 512-device dry-run lowers.  Gradient accumulation uses
``lax.scan`` over microbatches (sequential, activation-memory bounded).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer as tmod
from . import optimizer as opt_mod

MOE_LB_WEIGHT = 0.01
MOE_Z_WEIGHT = 1e-3
MTP_WEIGHT = 0.3


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  valid: jax.Array | None = None) -> jax.Array:
    """Mean CE over valid positions; logits fp32 (B,S,Vp), labels (B,S)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    ce = lse - gold
    if valid is None:
        valid = jnp.ones_like(ce, dtype=jnp.bool_)
    denom = jnp.maximum(valid.sum(), 1)
    return jnp.where(valid, ce, 0.0).sum() / denom


def loss_fn(params, cfg, batch, *, capacity: int | None = None):
    logits, aux, mtp_logits = tmod.forward(params, cfg, batch,
                                           capacity=capacity)
    labels = batch["labels"]
    valid = labels >= 0
    labels = jnp.maximum(labels, 0)
    ce = cross_entropy(logits, labels, valid)
    loss = ce + MOE_LB_WEIGHT * aux.moe_lb + MOE_Z_WEIGHT * aux.moe_z
    metrics = {"ce": ce, "moe_lb": aux.moe_lb, "moe_dropped": aux.moe_dropped}
    if mtp_logits is not None:  # deepseek MTP: position i predicts token i+2
        labels2 = jnp.roll(labels, -1, axis=1)
        valid2 = valid & (jnp.arange(labels.shape[1]) < labels.shape[1] - 1)
        mtp_ce = cross_entropy(mtp_logits, labels2, valid2)
        loss = loss + MTP_WEIGHT * mtp_ce
        metrics["mtp_ce"] = mtp_ce
    metrics["loss"] = loss
    return loss, metrics


def make_train_step(cfg, opt_cfg: opt_mod.AdamWConfig, *,
                    capacity: int | None = None, accum: int = 1):
    """Returns train_step(params, opt_state, batch)."""

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, capacity=capacity),
            has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if accum == 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            def micro(carry, mb):
                acc, msum = carry
                (_, m), g = grads_of(params, mb)
                acc = jax.tree_util.tree_map(jnp.add, acc, g)
                msum = jax.tree_util.tree_map(jnp.add, msum, m)
                return (acc, msum), None

            mb0 = jax.tree_util.tree_map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)
            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (_, m0), g0 = grads_of(params, jax.tree_util.tree_map(
                lambda x: x[0], mb0))
            (grads, msum), _ = jax.lax.scan(
                micro, (g0, m0),
                jax.tree_util.tree_map(lambda x: x[1:], mb0))
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
            metrics = jax.tree_util.tree_map(lambda m: m / accum, msum)
        new_params, new_state, om = opt_mod.apply_updates(
            opt_cfg, grads, opt_state, params)
        metrics.update(om)
        return new_params, new_state, metrics

    return train_step
