"""Markdown report generator for EXPERIMENTS.md tables."""
from __future__ import annotations

import glob
import json
import os


def roofline_table(dryrun_dir: str, mesh: str = "single") -> str:
    rows = []
    for p in sorted(glob.glob(os.path.join(dryrun_dir, f"*__{mesh}.json"))):
        r = json.load(open(p))
        rl = r["roofline"]
        dom = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        frac = rl["compute_s"] / dom if dom else 0.0
        rows.append((r["arch"], r["shape"], rl, frac,
                     r["memory_analysis"].get("temp_size", 0)))
    out = ["| arch | shape | compute_s | memory_s | collective_s | bottleneck "
           "| useful FLOPs (6ND/HLO) | roofline frac | temp GB/chip |",
           "|---|---|---|---|---|---|---|---|---|"]
    for arch, shape, rl, frac, temp in rows:
        out.append(
            f"| {arch} | {shape} | {rl['compute_s']:.3f} | {rl['memory_s']:.3f}"
            f" | {rl['collective_s']:.3f} | {rl['bottleneck']} |"
            f" {rl['useful_flops_ratio']:.2f} | {frac:.3f} |"
            f" {temp/2**30:.1f} |")
    return "\n".join(out)


def dryrun_summary(dryrun_dir: str) -> str:
    n = {"single": 0, "multi": 0}
    comp = []
    for p in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        r = json.load(open(p))
        n[r["mesh"]] += 1
        comp.append(r.get("compile_s", 0))
    return (f"{n['single']} single-pod + {n['multi']} multi-pod cells "
            f"compiled; median compile {sorted(comp)[len(comp)//2]:.0f}s")


if __name__ == "__main__":
    d = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                     "artifacts", "dryrun")
    print(dryrun_summary(d))
    print(roofline_table(d, "single"))
