"""Three-term roofline from a compiled dry-run artifact (DESIGN §8).

  compute   = flops_per_chip / PEAK_FLOPS
  memory    = hbm_bytes_per_chip / HBM_BW
  collective= collective_bytes_per_chip / LINK_BW

The SPMD-partitioned HLO is a per-device program, so ``cost_analysis()``
numbers and collective operand shapes are already per-chip; the prompt's
"global / chips" formulation is identical.

collective_bytes is parsed from the partitioned HLO text: the summed operand
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (fusion-wrapped instances included).
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np

# TPU v5e-class constants (per chip) from the assignment.
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
LINK_BW = 50e9             # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-reduce.5 = f32[16,4096]{1,0} all-reduce(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^\s]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
# tuple-typed collectives:  = (f32[..], f32[..]) all-to-all(
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind output bytes in the (per-device) HLO module."""
    out = {k: 0 for k in _COLLECTIVES}
    seen_done = set()
    for line in hlo_text.splitlines():
        if "-done(" in line:   # async pair: count only the start
            continue
        m = _OP_RE.search(line)
        if m:
            dtype, dims, kind = m.groups()
            out[kind] += _shape_bytes(dtype, dims)
            continue
        m = _TUPLE_RE.search(line)
        if m:
            shapes, kind = m.groups()
            for dt, dd in _SHAPE_RE.findall(shapes):
                out[kind] += _shape_bytes(dt, dd)
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    collective_bytes_per_chip: float
    collective_breakdown: dict
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float            # 6·N·D (or 6·N_active·D)
    useful_flops_ratio: float     # model_flops/chips / hlo flops_per_chip

    @staticmethod
    def build(flops_per_chip: float, hbm_bytes_per_chip: float,
              coll: dict[str, int], model_flops: float, chips: int):
        cb = float(sum(coll.values()))
        c = flops_per_chip / PEAK_FLOPS
        m = hbm_bytes_per_chip / HBM_BW
        k = cb / LINK_BW
        terms = {"compute": c, "memory": m, "collective": k}
        bn = max(terms, key=terms.get)
        ratio = (model_flops / chips) / flops_per_chip if flops_per_chip else 0.0
        return Roofline(flops_per_chip, hbm_bytes_per_chip, cb, coll,
                        c, m, k, bn, model_flops, ratio)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def model_flops_train(cfg, tokens: int) -> float:
    """6·N_active·D for one optimizer step over ``tokens`` tokens."""
    n = cfg.active_param_count_estimate()
    return 6.0 * n * tokens


def model_flops_decode(cfg, batch: int) -> float:
    """2·N_active per generated token (forward only) + attention reads."""
    n = cfg.active_param_count_estimate()
    return 2.0 * n * batch


def model_flops_prefill(cfg, tokens: int) -> float:
    return 2.0 * cfg.active_param_count_estimate() * tokens
