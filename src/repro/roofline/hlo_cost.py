"""Trip-count-aware cost model over the partitioned HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE — under a
scan-over-layers model that undercounts flops/bytes/collectives by the layer
count (verified on this backend; see EXPERIMENTS.md §Dry-run).  This module
re-derives the three roofline inputs from ``compiled.as_text()`` with loop
awareness:

  * the module is split into named computations,
  * a symbol table maps every instruction name → shape,
  * per computation we count dot flops (2·|out|·contracted), HBM bytes
    (operands + outputs of top-level instructions — fusion-internal traffic
    excluded, matching the classic bytes-accessed model), and collective
    operand bytes,
  * the call graph is walked from ENTRY: fusion/call = 1×, while = trip×
    (trip = the loop-bound constant in the condition computation),
    conditional = max over branches.

It is a *model* (elementwise flops inside fusions are not counted — matmul
flops dominate every cell here), reported next to the raw cost_analysis.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s*\(.*\{$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*"
    r"(\(.*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+([\w\-]+)\(")
_SHAPES = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPERANDS = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")
_CALLS = re.compile(r"calls=%([\w\.\-]+)")
_BODY = re.compile(r"body=%([\w\.\-]+)")
_CONDITION = re.compile(r"condition=%([\w\.\-]+)")
_TO_APPLY = re.compile(r"to_apply=%([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP = re.compile(r'known_trip_count..:..n.:.(\d+)')
_CONST = re.compile(r"constant\((\d+)\)")
_CDIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_list(type_str: str):
    return [(dt, [int(x) for x in dims.split(",") if x])
            for dt, dims in _SHAPES.findall(type_str)]


def _bytes_of(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_list(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str


def _parse_computations(hlo: str) -> dict[str, list[Instr]]:
    comps: dict[str, list[Instr]] = {}
    cur = None
    entry_alias = None
    for line in hlo.splitlines():
        s = line.rstrip()
        if cur is None:
            m = _COMP_HDR.match(s)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry_alias = cur
            continue
        if s.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(s)
        if not m:
            continue
        name, type_str, opcode = m.groups()
        comps[cur].append(Instr(name, type_str, opcode, s))
    if entry_alias:
        comps["__entry__"] = comps[entry_alias]
    return comps


def _symbol_table(comps) -> dict[str, str]:
    return {i.name: i.type_str for instrs in comps.values() for i in instrs}


_SKIP_BYTES = {"parameter", "get-tuple-element", "tuple", "constant",
               "bitcast", "while", "conditional", "call", "after-all",
               "iota", "get-dimension-size"}


def _operands_of(it: Instr) -> list[str]:
    i = it.rest.find(it.opcode + "(")
    if i < 0:
        return []
    m = _OPERANDS.match(it.rest[i + len(it.opcode):])
    if not m:
        return []
    body = m.group(1)
    # current jax prints operands with inline types whose layouts carry
    # commas ("f32[512,512]{1,0} %Arg_0.1"), so comma-splitting breaks —
    # the %-prefixed name tokens are the operands in both old and new text
    names = re.findall(r"%([\w\.\-]+)", body)
    if names:
        return names
    return [x.strip() for x in body.split(",") if x.strip()]


_SLICE_OPS = {"dynamic-slice", "gather", "slice"}


def _fusion_operand_bytes(fused_instrs):
    """HBM bytes a fusion reads from its operands.

    An operand whose only in-fusion consumers are dynamic-slice / gather /
    slice / dynamic-update-slice(target) contributes the SLICE bytes, not the
    full array: scan bodies dynamic-slice one layer out of stacked weights,
    and remat stacks are written in place via dus — counting the stack per
    iteration would overcount by the layer count.
    """
    params = {}
    for it in fused_instrs:
        if it.opcode == "parameter":
            params[it.name] = it.type_str
    consumers = {p: [] for p in params}
    for it in fused_instrs:
        if it.opcode == "parameter":
            continue
        for p in consumers:
            if "%" + p in it.rest:
                consumers[p].append(it)
    total = 0
    for p, ptype in params.items():
        cons = consumers[p]
        sliced = 0
        ok = bool(cons)
        for c in cons:
            if c.opcode in _SLICE_OPS:
                sliced += _bytes_of(c.type_str)
            elif c.opcode == "dynamic-update-slice":
                ops = _operands_of(c)
                if ops and ops[0] == p:
                    continue  # dus target: pure overwrite, no read
                ok = False
                break
            else:
                ok = False
                break
        total += sliced if ok else _bytes_of(ptype)
    return total


_PASSTHRU = {"bitcast", "copy", "reshape", "transpose", "tuple",
             "get-tuple-element", "convert"}


def _fusion_output_bytes(fused_instrs, out_type):
    """HBM bytes a fusion writes.  If the ROOT (through bitcast/copy chains)
    is a dynamic-update-slice of a pass-through parameter (in-place remat
    stack / KV-cache write under buffer aliasing), the true write is the
    UPDATE slice, not the whole buffer."""
    by_name = {it.name: it for it in fused_instrs}
    root = None
    for it in fused_instrs:
        if it.rest.lstrip().startswith("ROOT"):
            root = it
    hops = 0
    while root is not None and root.opcode in _PASSTHRU and hops < 8:
        ops = _operands_of(root)
        root = by_name.get(ops[0]) if ops else None
        hops += 1
    if root is not None and root.opcode == "dynamic-update-slice":
        ops = _operands_of(root)
        if len(ops) >= 2:
            upd = by_name.get(ops[1])
            if upd is not None:
                return _bytes_of(upd.type_str)
    return _bytes_of(out_type)


def _comp_costs(instrs, symbols, comps):
    """Local (non-recursive) flops / bytes / collective bytes + child calls."""
    flops = 0.0
    bytes_acc = 0.0
    coll = defaultdict(float)
    children = []  # (kind, names_or_pairs, instr)
    for it in instrs:
        op = it.opcode
        if op == "dot":
            out_n = 1
            for _, dims in _shape_list(it.type_str):
                for d in dims:
                    out_n *= d
            m = _CDIMS.search(it.rest)
            csize = 1
            if m:
                ops = _operands_of(it)
                lhs_type = symbols.get(ops[0], "") if ops else ""
                lhs_shapes = _shape_list(lhs_type)
                if lhs_shapes:
                    dims = lhs_shapes[0][1]
                    for ci in (int(x) for x in m.group(1).split(",") if x):
                        if ci < len(dims):
                            csize *= dims[ci]
            flops += 2.0 * out_n * csize
        base = op.replace("-start", "").replace("-done", "")
        if base in _COLLECTIVES and not op.endswith("-done"):
            coll[base] += _bytes_of(it.type_str)
        if op == "while":
            body = _BODY.search(it.rest)
            cond = _CONDITION.search(it.rest)
            trip_m = _TRIP.search(it.rest)
            trip = int(trip_m.group(1)) if trip_m else None
            children.append(("while",
                             (body.group(1) if body else None,
                              cond.group(1) if cond else None, trip), it))
        elif op == "conditional":
            b = _BRANCHES.search(it.rest)
            names = [x.strip().lstrip("%") for x in b.group(1).split(",")] if b else []
            children.append(("cond", names, it))
        else:
            names = _CALLS.findall(it.rest) + _TO_APPLY.findall(it.rest)
            if names:
                # reductions' tiny scalar to_apply bodies are negligible; only
                # walk fusions/calls whose bodies may contain dots/collectives
                if op in ("fusion", "call", "custom-call"):
                    children.append(("call", names, it))
        if op == "fusion":
            m = _CALLS.findall(it.rest)
            fused = comps.get(m[0], []) if m else []
            bytes_acc += _fusion_output_bytes(fused, it.type_str)
            if fused:
                bytes_acc += _fusion_operand_bytes(fused)
            else:
                for nm in _operands_of(it):
                    if nm in symbols:
                        bytes_acc += _bytes_of(symbols[nm])
        elif op == "dynamic-update-slice":
            ops_ = _operands_of(it)
            upd = symbols.get(ops_[1], "") if len(ops_) >= 2 else ""
            bytes_acc += 2 * _bytes_of(upd) if upd else _bytes_of(it.type_str)
        elif op not in _SKIP_BYTES:
            bytes_acc += _bytes_of(it.type_str)
    return flops, bytes_acc, dict(coll), children


def _trip_count(cond_instrs) -> int:
    best = 1
    for it in cond_instrs:
        for c in _CONST.findall(it.rest):
            best = max(best, int(c))
    return best


def analyze(hlo: str) -> dict:
    comps = _parse_computations(hlo)
    symbols = _symbol_table(comps)
    local = {name: _comp_costs(instrs, symbols, comps)
             for name, instrs in comps.items()}
    memo: dict[str, tuple] = {}

    def total(name: str, depth=0):
        if name in memo:
            return memo[name]
        if name not in local or depth > 64:
            return 0.0, 0.0, {}
        fl, by, co, children = local[name]
        co = dict(co)
        for kind, names, it in children:
            if kind == "while":
                body, cond, trip = names
                if trip is None:
                    trip = _trip_count(comps.get(cond, [])) if cond else 1
                bf, bb, bc = total(body, depth + 1) if body else (0, 0, {})
                fl += trip * bf
                by += trip * bb
                for k, v in bc.items():
                    co[k] = co.get(k, 0) + trip * v
            elif kind == "cond":
                branch_costs = [total(n, depth + 1) for n in names]
                if branch_costs:
                    bf = max(b[0] for b in branch_costs)
                    bi = max(range(len(branch_costs)),
                             key=lambda i: branch_costs[i][0])
                    fl += bf
                    by += branch_costs[bi][1]
                    for k, v in branch_costs[bi][2].items():
                        co[k] = co.get(k, 0) + v
            else:
                # fusion: flops and collectives propagate; internal bytes
                # are register/VMEM traffic, not HBM — excluded (the caller
                # counted the fusion's operand/output bytes).  call /
                # custom-call wrappers counted NO bytes at the call site
                # (current jax's parallel CPU backend wraps fusions in
                # call(to_apply=...)), so their bodies' HBM bytes propagate.
                passthru = it.opcode in ("call", "custom-call")
                for nm in names:
                    cf, cb, cc = total(nm, depth + 1)
                    fl += cf
                    if passthru:
                        by += cb
                    for k, v in cc.items():
                        co[k] = co.get(k, 0) + v
        memo[name] = (fl, by, co)
        return memo[name]

    fl, by, co = total("__entry__")
    return {"flops": fl, "bytes": by, "collectives": co,
            "collective_bytes": float(sum(co.values()))}
