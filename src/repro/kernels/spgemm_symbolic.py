"""Pallas kernel: the sampled symbolic phase of Algorithm 2 (TPU-native).

Per grid step: a block of sampled A rows.  The kernel

  1. gathers each sampled row's A columns (≤ DA) from VMEM,
  2. gathers every referenced B row's columns (≤ DB) — the intermediate
     product columns, a (BS, DA·DB→F2) buffer padded with COL_SENTINEL,
  3. bitonic-sorts the buffer along lanes (static network, DESIGN §3),
  4. counts strict ascents = exact distinct columns z*, and valid slots = f*.

Outputs per-block (z, f) partials; the tiny final reduction happens in XLA.
This is the hash-table replacement: identical result, zero data-dependent
control flow.  VMEM budget: BS·F2·4 bytes for the buffer (+ CSR arrays);
callers pick BS so that BS·F2 ≤ ~1M lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.csr import COL_SENTINEL
from .sortnet import bitonic_sort, next_pow2


def _kernel(rows_ref, a_rpt_ref, a_col_ref, b_rpt_ref, b_col_ref,
            rownnz_b_ref, z_ref, f_ref, *, block_samples: int,
            max_deg_a: int, max_deg_b: int):
    rows = rows_ref[...]                                        # (BS,)
    deg_a = a_rpt_ref[rows + 1] - a_rpt_ref[rows]
    ia = jax.lax.broadcasted_iota(jnp.int32, (block_samples, max_deg_a), 1)
    idx_a = jnp.clip(a_rpt_ref[rows][:, None] + ia, 0, a_col_ref.shape[0] - 1)
    valid_a = ia < deg_a[:, None]
    ks = jnp.where(valid_a, a_col_ref[idx_a], 0)                # (BS, DA)

    deg_b = jnp.where(valid_a, rownnz_b_ref[ks], 0)
    ib = jax.lax.broadcasted_iota(
        jnp.int32, (block_samples, max_deg_a, max_deg_b), 2)
    idx_b = jnp.clip(b_rpt_ref[ks][:, :, None] + ib, 0, b_col_ref.shape[0] - 1)
    valid = valid_a[:, :, None] & (ib < deg_b[:, :, None])
    cols = jnp.where(valid, b_col_ref[idx_b], COL_SENTINEL)

    f2 = next_pow2(max_deg_a * max_deg_b)
    buf = jnp.full((block_samples, f2), COL_SENTINEL, jnp.int32)
    buf = buf.at[:, : max_deg_a * max_deg_b].set(
        cols.reshape(block_samples, -1))
    srt = bitonic_sort(buf)
    first = (srt[:, :1] != COL_SENTINEL).astype(jnp.int32)
    ascents = ((srt[:, 1:] != srt[:, :-1]) &
               (srt[:, 1:] != COL_SENTINEL)).astype(jnp.int32)
    z_ref[...] = (first[:, 0] + ascents.sum(axis=-1)).sum(keepdims=True)
    f_ref[...] = valid.astype(jnp.int32).reshape(block_samples, -1).sum(
        axis=-1).sum(keepdims=True)


@functools.partial(jax.jit, static_argnames=(
    "max_deg_a", "max_deg_b", "block_samples", "interpret"))
def sampled_symbolic_pallas(a_rpt, a_col, b_rpt, b_col, rows, *,
                            max_deg_a: int, max_deg_b: int,
                            block_samples: int = 8, interpret: bool = True):
    """Returns (z*, f*) — exact sampled NNZ and sampled FLOP (int32 scalars)."""
    s = rows.shape[0]
    nblocks = -(-s // block_samples)
    pad_s = nblocks * block_samples
    # pad with repeats of row 0, subtract its duplicate contribution after
    rows_p = jnp.concatenate(
        [rows.astype(jnp.int32),
         jnp.zeros(pad_s - s, jnp.int32)]) if pad_s != s else rows.astype(jnp.int32)
    rownnz_b = jnp.diff(b_rpt)
    z_b, f_b = pl.pallas_call(
        functools.partial(_kernel, block_samples=block_samples,
                          max_deg_a=max_deg_a, max_deg_b=max_deg_b),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block_samples,), lambda i: (i,)),  # rows: blocked
            pl.BlockSpec(memory_space=pl.ANY),               # a_rpt
            pl.BlockSpec(memory_space=pl.ANY),               # a_col
            pl.BlockSpec(memory_space=pl.ANY),               # b_rpt
            pl.BlockSpec(memory_space=pl.ANY),               # b_col
            pl.BlockSpec(memory_space=pl.ANY),               # rownnz_b
        ],
        out_specs=[pl.BlockSpec((1,), lambda i: (i,)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((nblocks,), jnp.int32),
                   jax.ShapeDtypeStruct((nblocks,), jnp.int32)],
        interpret=interpret,
    )(rows_p, a_rpt, a_col, b_rpt, b_col, rownnz_b)
    z, f = z_b.sum(), f_b.sum()
    if pad_s != s:  # remove the padded duplicates of row 0
        from repro.core.predictor import gather_sampled_products, count_distinct_sorted
        # cheap correction: recompute row 0's (z, f) once in jnp
        pad = pad_s - s
        r0 = jnp.zeros((1,), jnp.int32)
        deg_a0 = a_rpt[1] - a_rpt[0]
        ia = jnp.arange(max_deg_a, dtype=jnp.int32)
        idx_a = jnp.clip(a_rpt[0] + ia, 0, a_col.shape[0] - 1)
        va = ia < deg_a0
        ks = jnp.where(va, a_col[idx_a], 0)
        deg_b = jnp.where(va, rownnz_b[ks], 0)
        ib = jnp.arange(max_deg_b, dtype=jnp.int32)
        idx_b = jnp.clip(b_rpt[ks][:, None] + ib[None, :], 0, b_col.shape[0] - 1)
        vb = va[:, None] & (ib[None, :] < deg_b[:, None])
        cols0 = jnp.where(vb, b_col[idx_b], COL_SENTINEL).reshape(1, -1)
        srt0 = jnp.sort(cols0, axis=-1)
        z0 = ((srt0[:, :1] != COL_SENTINEL).astype(jnp.int32).sum() +
              ((srt0[:, 1:] != srt0[:, :-1]) & (srt0[:, 1:] != COL_SENTINEL)).sum())
        f0 = vb.sum()
        z = z - pad * z0
        f = f - pad * f0
    return z, f
