"""Pallas kernel: the sampled symbolic phase of Algorithm 2 (TPU-native).

Per grid step: a block of sampled A rows.  The kernel

  1. gathers each sampled row's A columns (≤ DA) from VMEM,
  2. gathers every referenced B row's columns (≤ DB) — the intermediate
     product columns, a (BS, DA·DB→F2) buffer padded with COL_SENTINEL,
  3. bitonic-sorts the buffer along lanes (static network, DESIGN §3),
  4. counts strict ascents = exact distinct columns z*, and valid slots = f*.

Outputs per-block (z, f) partials; the tiny final reduction happens in XLA.
This is the hash-table replacement: identical result, zero data-dependent
control flow.  VMEM budget: BS·F2·4 bytes for the buffer (+ CSR arrays);
callers pick BS so that BS·F2 ≤ ~1M lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.csr import COL_SENTINEL, pad_row_ids
from .sortnet import bitonic_sort, pad_to_pow2


def _kernel(rows_ref, a_rpt_ref, a_col_ref, b_rpt_ref, b_col_ref,
            rownnz_b_ref, z_ref, f_ref, *, block_samples: int,
            max_deg_a: int, max_deg_b: int, n_valid: int):
    rows = rows_ref[...]                                        # (BS,)
    i = pl.program_id(0)
    pos = i * block_samples + jax.lax.broadcasted_iota(
        jnp.int32, (block_samples,), 0)
    row_ok = pos < n_valid            # block-padding rows contribute nothing
    deg_a = a_rpt_ref[rows + 1] - a_rpt_ref[rows]
    ia = jax.lax.broadcasted_iota(jnp.int32, (block_samples, max_deg_a), 1)
    idx_a = jnp.clip(a_rpt_ref[rows][:, None] + ia, 0, a_col_ref.shape[0] - 1)
    valid_a = row_ok[:, None] & (ia < deg_a[:, None])
    ks = jnp.where(valid_a, a_col_ref[idx_a], 0)                # (BS, DA)

    deg_b = jnp.where(valid_a, rownnz_b_ref[ks], 0)
    ib = jax.lax.broadcasted_iota(
        jnp.int32, (block_samples, max_deg_a, max_deg_b), 2)
    idx_b = jnp.clip(b_rpt_ref[ks][:, :, None] + ib, 0, b_col_ref.shape[0] - 1)
    valid = valid_a[:, :, None] & (ib < deg_b[:, :, None])
    cols = jnp.where(valid, b_col_ref[idx_b], COL_SENTINEL)

    buf, _ = pad_to_pow2(cols.reshape(block_samples, -1), None, COL_SENTINEL)
    srt = bitonic_sort(buf)
    first = (srt[:, :1] != COL_SENTINEL).astype(jnp.int32)
    ascents = ((srt[:, 1:] != srt[:, :-1]) &
               (srt[:, 1:] != COL_SENTINEL)).astype(jnp.int32)
    z_ref[...] = (first[:, 0] + ascents.sum(axis=-1)).sum(keepdims=True)
    f_ref[...] = valid.astype(jnp.int32).reshape(block_samples, -1).sum(
        axis=-1).sum(keepdims=True)


def _fused_kernel(rows_ref, a_rpt_ref, a_col_ref, b_rpt_ref, b_col_ref,
                  rownnz_b_ref, z_ref, f_ref, flop_ref, *, block_samples: int,
                  max_deg_a: int, max_deg_b: int, n_valid: int):
    """Fused Algorithm 1 + Algorithm 2 body for one block of sampled rows.

    The A-row gather (``ks``/``valid_a``) and the B-degree lookup are shared:
    FLOP-per-sampled-row is a lane reduction over ``deg_b`` while the same
    ``deg_b`` drives the product-column expansion that the bitonic distinct
    count consumes.  Rows at positions ≥ ``n_valid`` are block padding and
    contribute nothing (no duplicate-correction pass needed).
    """
    i = pl.program_id(0)
    pos = i * block_samples + jax.lax.broadcasted_iota(
        jnp.int32, (block_samples,), 0)
    row_ok = pos < n_valid                                      # (BS,)
    rows = rows_ref[...]
    deg_a = a_rpt_ref[rows + 1] - a_rpt_ref[rows]
    ia = jax.lax.broadcasted_iota(jnp.int32, (block_samples, max_deg_a), 1)
    idx_a = jnp.clip(a_rpt_ref[rows][:, None] + ia, 0, a_col_ref.shape[0] - 1)
    valid_a = row_ok[:, None] & (ia < deg_a[:, None])
    ks = jnp.where(valid_a, a_col_ref[idx_a], 0)                # (BS, DA)

    deg_b = jnp.where(valid_a, rownnz_b_ref[ks], 0)
    flop = deg_b.sum(axis=1).astype(jnp.int32)                  # (BS,)

    ib = jax.lax.broadcasted_iota(
        jnp.int32, (block_samples, max_deg_a, max_deg_b), 2)
    idx_b = jnp.clip(b_rpt_ref[ks][:, :, None] + ib, 0, b_col_ref.shape[0] - 1)
    valid = valid_a[:, :, None] & (ib < deg_b[:, :, None])
    cols = jnp.where(valid, b_col_ref[idx_b], COL_SENTINEL)

    buf, _ = pad_to_pow2(cols.reshape(block_samples, -1), None, COL_SENTINEL)
    srt = bitonic_sort(buf)
    first = (srt[:, :1] != COL_SENTINEL).astype(jnp.int32)
    ascents = ((srt[:, 1:] != srt[:, :-1]) &
               (srt[:, 1:] != COL_SENTINEL)).astype(jnp.int32)
    z_ref[...] = (first[:, 0] + ascents.sum(axis=-1)).sum(keepdims=True)
    f_ref[...] = flop.sum(keepdims=True)
    flop_ref[...] = flop


@functools.partial(jax.jit, static_argnames=(
    "max_deg_a", "max_deg_b", "block_samples", "interpret"))
def fused_flop_symbolic_pallas(a_rpt, a_col, b_rpt, b_col, rows, *,
                               max_deg_a: int, max_deg_b: int,
                               block_samples: int = 8, interpret: bool = True):
    """One pallas_call → (z*, f*, flop-per-sampled-row (S,)).

    The binned predictor issues this once per bucket: the sampled symbolic
    pass and the sampled rows' FLOP share a single A-row gather instead of
    the two separate kernel sweeps of the unfused path.
    """
    s = rows.shape[0]
    nblocks = -(-s // block_samples)
    pad_s = nblocks * block_samples
    rows_p = pad_row_ids(rows, block_samples)  # masked in-kernel via n_valid
    rownnz_b = jnp.diff(b_rpt)
    z_b, f_b, flop = pl.pallas_call(
        functools.partial(_fused_kernel, block_samples=block_samples,
                          max_deg_a=max_deg_a, max_deg_b=max_deg_b,
                          n_valid=s),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block_samples,), lambda i: (i,)),  # rows: blocked
            pl.BlockSpec(memory_space=pl.ANY),               # a_rpt
            pl.BlockSpec(memory_space=pl.ANY),               # a_col
            pl.BlockSpec(memory_space=pl.ANY),               # b_rpt
            pl.BlockSpec(memory_space=pl.ANY),               # b_col
            pl.BlockSpec(memory_space=pl.ANY),               # rownnz_b
        ],
        out_specs=[pl.BlockSpec((1,), lambda i: (i,)),
                   pl.BlockSpec((1,), lambda i: (i,)),
                   pl.BlockSpec((block_samples,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((nblocks,), jnp.int32),
                   jax.ShapeDtypeStruct((nblocks,), jnp.int32),
                   jax.ShapeDtypeStruct((pad_s,), jnp.int32)],
        interpret=interpret,
    )(rows_p, a_rpt, a_col, b_rpt, b_col, rownnz_b)
    return z_b.sum(), f_b.sum(), flop[:s]


@functools.partial(jax.jit, static_argnames=(
    "max_deg_a", "max_deg_b", "block_samples", "interpret"))
def sampled_symbolic_pallas(a_rpt, a_col, b_rpt, b_col, rows, *,
                            max_deg_a: int, max_deg_b: int,
                            block_samples: int = 8, interpret: bool = True):
    """Returns (z*, f*) — exact sampled NNZ and sampled FLOP (int32 scalars)."""
    s = rows.shape[0]
    nblocks = -(-s // block_samples)
    rows_p = pad_row_ids(rows, block_samples)  # masked in-kernel via n_valid
    rownnz_b = jnp.diff(b_rpt)
    z_b, f_b = pl.pallas_call(
        functools.partial(_kernel, block_samples=block_samples,
                          max_deg_a=max_deg_a, max_deg_b=max_deg_b,
                          n_valid=s),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block_samples,), lambda i: (i,)),  # rows: blocked
            pl.BlockSpec(memory_space=pl.ANY),               # a_rpt
            pl.BlockSpec(memory_space=pl.ANY),               # a_col
            pl.BlockSpec(memory_space=pl.ANY),               # b_rpt
            pl.BlockSpec(memory_space=pl.ANY),               # b_col
            pl.BlockSpec(memory_space=pl.ANY),               # rownnz_b
        ],
        out_specs=[pl.BlockSpec((1,), lambda i: (i,)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((nblocks,), jnp.int32),
                   jax.ShapeDtypeStruct((nblocks,), jnp.int32)],
        interpret=interpret,
    )(rows_p, a_rpt, a_col, b_rpt, b_col, rownnz_b)
    return z_b.sum(), f_b.sum()
