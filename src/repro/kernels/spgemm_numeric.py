"""Pallas kernel: row-wise numeric SpGEMM accumulation (TPU-native).

Per grid step (a block of output rows): gather the intermediate products
(columns AND value-products) into a static (BS, F2) buffer, bitonic-sort the
key/value pairs, then compute per-run value sums with the log-step segmented
scan.  The kernel emits the *uncompacted* sorted buffer: sorted columns, a
first-of-run mask, and run-sums placed at each run's first slot.

The O(F log F) sort + O(F log F) segmented scan — the expensive part — stays
in the kernel; the O(F) compaction into the predicted-capacity CSR buffers is
a cheap XLA scatter outside (see ``repro.core.spgemm`` / ``ops.py``).  This
split keeps the kernel free of VMEM scatters while the MXU-unfriendly memory
traffic is still one pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.csr import COL_SENTINEL, pad_row_ids
from .sortnet import (bitonic_sort_pairs, segmented_run_sums, next_pow2,
                      pad_to_pow2)


def _kernel(rows_ref, a_rpt_ref, a_col_ref, a_val_ref, b_rpt_ref, b_col_ref,
            b_val_ref, rownnz_b_ref, col_out_ref, val_out_ref, first_out_ref,
            *, block_rows: int, max_deg_a: int, max_deg_b: int):
    rows = rows_ref[...]
    deg_a = a_rpt_ref[rows + 1] - a_rpt_ref[rows]
    ia = jax.lax.broadcasted_iota(jnp.int32, (block_rows, max_deg_a), 1)
    idx_a = jnp.clip(a_rpt_ref[rows][:, None] + ia, 0, a_col_ref.shape[0] - 1)
    valid_a = ia < deg_a[:, None]
    ks = jnp.where(valid_a, a_col_ref[idx_a], 0)
    av = jnp.where(valid_a, a_val_ref[idx_a], 0.0)

    deg_b = jnp.where(valid_a, rownnz_b_ref[ks], 0)
    ib = jax.lax.broadcasted_iota(
        jnp.int32, (block_rows, max_deg_a, max_deg_b), 2)
    idx_b = jnp.clip(b_rpt_ref[ks][:, :, None] + ib, 0, b_col_ref.shape[0] - 1)
    valid = valid_a[:, :, None] & (ib < deg_b[:, :, None])
    cols = jnp.where(valid, b_col_ref[idx_b], COL_SENTINEL)
    vals = jnp.where(valid, av[:, :, None] * b_val_ref[idx_b], 0.0)

    f = max_deg_a * max_deg_b
    cbuf, vbuf = pad_to_pow2(cols.reshape(block_rows, f),
                             vals.reshape(block_rows, f), COL_SENTINEL)
    c_s, v_s = bitonic_sort_pairs(cbuf, vbuf)
    first, run_sums = segmented_run_sums(c_s, v_s, COL_SENTINEL)
    col_out_ref[...] = c_s
    val_out_ref[...] = jnp.where(first, run_sums, 0.0)
    first_out_ref[...] = first.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=(
    "max_deg_a", "max_deg_b", "block_rows", "interpret"))
def spgemm_numeric_pallas(a_rpt, a_col, a_val, b_rpt, b_col, b_val, rows, *,
                          max_deg_a: int, max_deg_b: int, block_rows: int = 8,
                          interpret: bool = True, rownnz_b=None):
    """Sorted/run-summed products for ``rows``.

    Returns (sorted_cols (R, F2), run_sums_at_first (R, F2), first_mask (R, F2)).
    ``rownnz_b`` (= ``jnp.diff(b_rpt)``) may be passed in so bucket-iterated
    callers hoist the diff out of their per-bucket calls.
    """
    r = rows.shape[0]
    nblocks = -(-r // block_rows)
    pad_r = nblocks * block_rows
    rows_p = pad_row_ids(rows, block_rows)
    if rownnz_b is None:
        rownnz_b = jnp.diff(b_rpt)
    f2 = next_pow2(max_deg_a * max_deg_b)
    cols, vals, first = pl.pallas_call(
        functools.partial(_kernel, block_rows=block_rows,
                          max_deg_a=max_deg_a, max_deg_b=max_deg_b),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[pl.BlockSpec((block_rows, f2), lambda i: (i, 0)),
                   pl.BlockSpec((block_rows, f2), lambda i: (i, 0)),
                   pl.BlockSpec((block_rows, f2), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((pad_r, f2), jnp.int32),
                   jax.ShapeDtypeStruct((pad_r, f2), jnp.float32),
                   jax.ShapeDtypeStruct((pad_r, f2), jnp.int32)],
        interpret=interpret,
    )(rows_p, a_rpt, a_col, a_val, b_rpt, b_col, b_val, rownnz_b)
    return cols[:r], vals[:r], first[:r]


def compact(cols, vals, first, row_capacity: int):
    """XLA-side compaction into predicted-capacity buffers (cheap O(F))."""
    seg = jnp.cumsum(first, axis=-1) - 1
    valid = first.astype(bool)
    seg_sc = jnp.where(valid, seg, row_capacity)
    r = cols.shape[0]
    rows_ix = jnp.broadcast_to(jnp.arange(r)[:, None], seg_sc.shape)
    out_val = jnp.zeros((r, row_capacity), jnp.float32).at[
        rows_ix, seg_sc].add(vals, mode="drop")
    out_col = jnp.full((r, row_capacity), COL_SENTINEL, jnp.int32).at[
        rows_ix, seg_sc].min(cols, mode="drop")
    row_nnz = seg[:, -1] + 1
    overflow = jnp.maximum(row_nnz - row_capacity, 0).sum()
    return out_col, out_val, row_nnz, overflow
