"""Pallas kernel: Algorithm 1 — FLOP per output row.

Grid: one step per block of ``block_rows`` output rows.  The CSR index arrays
(A.rpt, A.col, B row-nnz) are VMEM-resident (no blocking — they are small for
the sampled workloads this feeds; a production variant adds a second grid dim
streaming A.col).  The per-block work is a contiguous dynamic slice of A.rpt,
a 2-D gather from A.col, a gather of B row-nnz and a lane reduction — MXU-free
pure VPU, hardware-aligned when block_rows % 8 == 0 and max_deg_a % 128 == 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.csr import pad_row_ids


def _kernel(rpt_ref, col_ref, rownnz_b_ref, out_ref, *, block_rows: int,
            max_deg_a: int):
    i = pl.program_id(0)
    row0 = i * block_rows
    starts = pl.load(rpt_ref, (pl.dslice(row0, block_rows),))
    ends = pl.load(rpt_ref, (pl.dslice(row0 + 1, block_rows),))
    deg = ends - starts                                         # (BR,)
    ia = jax.lax.broadcasted_iota(jnp.int32, (block_rows, max_deg_a), 1)
    idx = starts[:, None] + ia                                  # (BR, DA)
    valid = ia < deg[:, None]
    cap = col_ref.shape[0]
    cols = col_ref[jnp.clip(idx, 0, cap - 1)]                   # VMEM gather
    b_nnz = rownnz_b_ref[jnp.clip(cols, 0, rownnz_b_ref.shape[0] - 1)]
    contrib = jnp.where(valid, b_nnz, 0)
    out_ref[...] = jnp.sum(contrib, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_rows", "max_deg_a", "interpret"))
def flop_per_row_pallas(rpt: jax.Array, col: jax.Array, rownnz_b: jax.Array,
                        *, block_rows: int = 256, max_deg_a: int = 128,
                        interpret: bool = True) -> jax.Array:
    """floprC for all M rows.  ``rpt`` int32 (M+1,), ``col`` int32 (cap,)."""
    m = rpt.shape[0] - 1
    nblocks = -(-m // block_rows)
    pad_m = nblocks * block_rows
    # pad rpt so every block's [row0, row0+BR] slice is in range; padded rows
    # have deg 0 (rpt repeats its last entry).
    rpt_p = jnp.concatenate(
        [rpt, jnp.broadcast_to(rpt[-1:], (pad_m + 1 - rpt.shape[0],))])
    out = pl.pallas_call(
        functools.partial(_kernel, block_rows=block_rows, max_deg_a=max_deg_a),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),   # rpt: full, VMEM
            pl.BlockSpec(memory_space=pl.ANY),   # col: full, VMEM
            pl.BlockSpec(memory_space=pl.ANY),   # rownnz_b: full, VMEM
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((pad_m,), jnp.int32),
        interpret=interpret,
    )(rpt_p, col, rownnz_b)
    return out[:m]


def _rows_kernel(rows_ref, rpt_ref, col_ref, rownnz_b_ref, out_ref, *,
                 block_rows: int, max_deg_a: int):
    """Same reduction, but over an explicit row-id list (a degree bucket)."""
    rows = rows_ref[...]                                        # (BR,)
    starts = rpt_ref[rows]
    deg = rpt_ref[rows + 1] - starts
    ia = jax.lax.broadcasted_iota(jnp.int32, (block_rows, max_deg_a), 1)
    idx = jnp.clip(starts[:, None] + ia, 0, col_ref.shape[0] - 1)
    valid = ia < deg[:, None]
    cols = col_ref[idx]
    b_nnz = rownnz_b_ref[jnp.clip(cols, 0, rownnz_b_ref.shape[0] - 1)]
    out_ref[...] = jnp.sum(jnp.where(valid, b_nnz, 0), axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_rows", "max_deg_a", "interpret"))
def flop_rows_pallas(rpt: jax.Array, col: jax.Array, rownnz_b: jax.Array,
                     rows: jax.Array, *, block_rows: int = 256,
                     max_deg_a: int = 128, interpret: bool = True) -> jax.Array:
    """floprC for the listed ``rows`` only — the binned-pipeline variant,
    sized by the bucket's degree bound instead of the global one."""
    r = rows.shape[0]
    nblocks = -(-r // block_rows)
    pad_r = nblocks * block_rows
    rows_p = pad_row_ids(rows, block_rows)
    out = pl.pallas_call(
        functools.partial(_rows_kernel, block_rows=block_rows,
                          max_deg_a=max_deg_a),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block_rows,), lambda i: (i,)),  # rows: blocked
            pl.BlockSpec(memory_space=pl.ANY),            # rpt
            pl.BlockSpec(memory_space=pl.ANY),            # col
            pl.BlockSpec(memory_space=pl.ANY),            # rownnz_b
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((pad_r,), jnp.int32),
        interpret=interpret,
    )(rows_p, rpt, col, rownnz_b)
    return out[:r]
