"""Pallas kernel: blocked flash attention (the LM stack's compute hot-spot).

Canonical two-dimensional grid — ``(q_blocks, k_blocks)`` per (batch, head) —
with VMEM scratch carrying the online-softmax state (running max m, denominator
l, and the output accumulator).  K/V blocks stream through VMEM via BlockSpec;
causal blocks strictly above the diagonal are predicated off with ``pl.when``.
MXU-aligned when block_q/block_k are multiples of 128 and head_dim ∈ {64,128}.

Numerics: fp32 accumulation regardless of input dtype; masked logits use a
finite -1e30 and masked probabilities are zeroed explicitly so fully-masked
blocks cannot pollute the denominator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _compiler_params():
    """The TPU compiler-params class was renamed across JAX releases
    (CompilerParams ↔ TPUCompilerParams); resolve whichever exists."""
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(dimension_semantics=("parallel", "arbitrary"))


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  sm_scale: float, causal: bool, block_q: int, block_k: int):
    qi = pl.program_id(0)
    kj = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: skip blocks entirely above the diagonal
    run = (kj * block_k <= qi * block_q + block_q - 1) if causal else (kj >= 0)

    @pl.when(run)
    def _compute():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale      # (bq, bk)
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = kj * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            mask = qpos >= kpos
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_cur[:, None])
        if causal:
            p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_cur)
        l_scr[...] = l_prev * alpha + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_cur

    @pl.when(kj == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "block_q", "block_k", "interpret"))
def _flash_single(q, k, v, *, causal: bool, block_q: int, block_k: int,
                  interpret: bool):
    """q (sq, d), k/v (sk, d) → (sq, d)."""
    sq, d = q.shape
    sk = k.shape[0]
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    sm_scale = 1.0 / (d ** 0.5)
    grid = (sq // block_q, sk // block_k)
    return pl.pallas_call(
        functools.partial(_flash_kernel, sm_scale=sm_scale, causal=causal,
                          block_q=block_q, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_k, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_k, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(q, k, v)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None):
    """Batched GQA flash attention.

    q: (batch, q_heads, sq, d); k, v: (batch, kv_heads, sk, d) with
    q_heads % kv_heads == 0.  Returns (batch, q_heads, sq, d).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0
    group = hq // hkv
    qg = q.reshape(b, hkv, group, sq, d)
    fn = functools.partial(_flash_single, causal=causal, block_q=block_q,
                           block_k=block_k, interpret=interpret)
    # vmap over batch, kv-head, and query-group
    out = jax.vmap(jax.vmap(jax.vmap(fn, in_axes=(0, None, None)),
                            in_axes=(0, 0, 0)),
                   in_axes=(0, 0, 0))(qg, k, v)
    return out.reshape(b, hq, sq, d)
