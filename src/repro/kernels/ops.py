"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels run in ``interpret=True``; on TPU they lower
natively.  Every wrapper has an identically-shaped oracle in ``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.csr import CSRDevice
from . import flop_per_row as _flop_k
from . import spgemm_symbolic as _sym_k
from . import spgemm_numeric as _num_k
from . import flash_attention as _fa_k


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def flop_per_row(a: CSRDevice, b: CSRDevice, *, block_rows: int = 256,
                 max_deg_a: int = 128) -> jax.Array:
    rownnz_b = jnp.diff(b.rpt)
    return _flop_k.flop_per_row_pallas(
        a.rpt, a.col, rownnz_b, block_rows=block_rows, max_deg_a=max_deg_a,
        interpret=_interpret())


def flop_rows(a: CSRDevice, b: CSRDevice, rows: jax.Array, *,
              max_deg_a: int, block_rows: int = 256) -> jax.Array:
    """floprC for the listed rows only (binned-pipeline flop phase)."""
    rownnz_b = jnp.diff(b.rpt)
    return _flop_k.flop_rows_pallas(
        a.rpt, a.col, rownnz_b, rows, block_rows=block_rows,
        max_deg_a=max_deg_a, interpret=_interpret())


def sampled_symbolic(a: CSRDevice, b: CSRDevice, rows: jax.Array,
                     max_deg_a: int, max_deg_b: int,
                     block_samples: int = 8) -> tuple[jax.Array, jax.Array]:
    """(z*, f*) for the proposed predictor (kernel path)."""
    return _sym_k.sampled_symbolic_pallas(
        a.rpt, a.col, b.rpt, b.col, rows, max_deg_a=max_deg_a,
        max_deg_b=max_deg_b, block_samples=block_samples,
        interpret=_interpret())


def fused_flop_symbolic(a: CSRDevice, b: CSRDevice, rows: jax.Array,
                        max_deg_a: int, max_deg_b: int,
                        block_samples: int = 8):
    """(z*, f*, flop-per-sampled-row) in ONE kernel — the binned predictor's
    per-bucket invocation (flop + symbolic share the A-row gather)."""
    return _sym_k.fused_flop_symbolic_pallas(
        a.rpt, a.col, b.rpt, b.col, rows, max_deg_a=max_deg_a,
        max_deg_b=max_deg_b, block_samples=block_samples,
        interpret=_interpret())


def spgemm_numeric(a: CSRDevice, b: CSRDevice, rows: jax.Array, *,
                   max_deg_a: int, max_deg_b: int, row_capacity: int,
                   block_rows: int = 8):
    """Kernel numeric phase + XLA compaction → (col, val, row_nnz, overflow)."""
    cols, vals, first = _num_k.spgemm_numeric_pallas(
        a.rpt, a.col, a.val, b.rpt, b.col, b.val, rows,
        max_deg_a=max_deg_a, max_deg_b=max_deg_b, block_rows=block_rows,
        interpret=_interpret())
    return _num_k.compact(cols, vals, first, row_capacity)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    return _fa_k.flash_attention(q, k, v, causal=causal, block_q=block_q,
                                 block_k=block_k, interpret=_interpret())
