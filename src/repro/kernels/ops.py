"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels run in ``interpret=True``; on TPU they lower
natively.  Every wrapper has an identically-shaped oracle in ``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.csr import CSRDevice
from repro.core.binning import ROUTE_ESC, ROUTE_SPA
from . import flop_per_row as _flop_k
from . import spgemm_symbolic as _sym_k
from . import spgemm_numeric as _num_k
from . import accumulator as _acc_k
from . import flash_attention as _fa_k


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _check_route(route: str) -> None:
    # routes are static plan metadata — an unknown string would otherwise
    # silently fall through to the ESC path and mask a planner bug
    if route not in (ROUTE_ESC, ROUTE_SPA):
        from repro.core.errors import PlanMismatchError
        raise PlanMismatchError(f"unknown kernel route {route!r}")


def flop_per_row(a: CSRDevice, b: CSRDevice, *, block_rows: int = 256,
                 max_deg_a: int = 128) -> jax.Array:
    rownnz_b = jnp.diff(b.rpt)
    return _flop_k.flop_per_row_pallas(
        a.rpt, a.col, rownnz_b, block_rows=block_rows, max_deg_a=max_deg_a,
        interpret=_interpret())


def flop_rows(a: CSRDevice, b: CSRDevice, rows: jax.Array, *,
              max_deg_a: int, block_rows: int = 256) -> jax.Array:
    """floprC for the listed rows only (binned-pipeline flop phase)."""
    rownnz_b = jnp.diff(b.rpt)
    return _flop_k.flop_rows_pallas(
        a.rpt, a.col, rownnz_b, rows, block_rows=block_rows,
        max_deg_a=max_deg_a, interpret=_interpret())


def sampled_symbolic(a: CSRDevice, b: CSRDevice, rows: jax.Array,
                     max_deg_a: int, max_deg_b: int,
                     block_samples: int = 8) -> tuple[jax.Array, jax.Array]:
    """(z*, f*) for the proposed predictor (kernel path)."""
    return _sym_k.sampled_symbolic_pallas(
        a.rpt, a.col, b.rpt, b.col, rows, max_deg_a=max_deg_a,
        max_deg_b=max_deg_b, block_samples=block_samples,
        interpret=_interpret())


def fused_flop_symbolic(a: CSRDevice, b: CSRDevice, rows: jax.Array,
                        max_deg_a: int, max_deg_b: int,
                        block_samples: int = 8):
    """(z*, f*, flop-per-sampled-row) in ONE kernel — the binned predictor's
    per-bucket invocation (flop + symbolic share the A-row gather)."""
    return _sym_k.fused_flop_symbolic_pallas(
        a.rpt, a.col, b.rpt, b.col, rows, max_deg_a=max_deg_a,
        max_deg_b=max_deg_b, block_samples=block_samples,
        interpret=_interpret())


def bitmask_symbolic(a: CSRDevice, b: CSRDevice, rows: jax.Array,
                     max_deg_a: int, max_deg_b: int,
                     block_samples: int = 8, span: int = 0,
                     rownnz_b=None) -> tuple[jax.Array, jax.Array]:
    """(z*, f*) via the bitmask-popcount kernel (SPA symbolic route) —
    bit-equal to :func:`sampled_symbolic`.  ``span`` is the planner's bound
    on per-row product-column extent (0 → full column space)."""
    return _acc_k.bitmask_symbolic_pallas(
        a.rpt, a.col, b.rpt, b.col, rows, max_deg_a=max_deg_a,
        max_deg_b=max_deg_b, ncols_b=b.ncols, span=span,
        block_samples=block_samples, interpret=_interpret(),
        rownnz_b=rownnz_b)


def fused_flop_symbolic_routed(a: CSRDevice, b: CSRDevice, rows: jax.Array, *,
                               max_deg_a: int, max_deg_b: int,
                               route: str = ROUTE_ESC, span: int = 0,
                               block_samples: int = 8, rownnz_b=None):
    """Route-dispatched fused (z*, f*, flop) — the binned predictor's single
    per-bucket Pallas invocation.  The route is static plan metadata
    (``RowBucket.route``), so dispatch costs nothing at runtime."""
    _check_route(route)
    if route == ROUTE_SPA:
        return _acc_k.fused_flop_symbolic_bitmask_pallas(
            a.rpt, a.col, b.rpt, b.col, rows, max_deg_a=max_deg_a,
            max_deg_b=max_deg_b, ncols_b=b.ncols, span=span,
            block_samples=block_samples, interpret=_interpret(),
            rownnz_b=rownnz_b)
    return _sym_k.fused_flop_symbolic_pallas(
        a.rpt, a.col, b.rpt, b.col, rows, max_deg_a=max_deg_a,
        max_deg_b=max_deg_b, block_samples=block_samples,
        interpret=_interpret())


def spgemm_numeric(a: CSRDevice, b: CSRDevice, rows: jax.Array, *,
                   max_deg_a: int, max_deg_b: int, row_capacity: int,
                   block_rows: int = 8, rownnz_b=None):
    """Kernel numeric phase + XLA compaction → (col, val, row_nnz, overflow)."""
    cols, vals, first = _num_k.spgemm_numeric_pallas(
        a.rpt, a.col, a.val, b.rpt, b.col, b.val, rows,
        max_deg_a=max_deg_a, max_deg_b=max_deg_b, block_rows=block_rows,
        interpret=_interpret(), rownnz_b=rownnz_b)
    return _num_k.compact(cols, vals, first, row_capacity)


def spgemm_numeric_spa(a: CSRDevice, b: CSRDevice, rows: jax.Array, *,
                       max_deg_a: int, max_deg_b: int, row_capacity: int,
                       tile_n: int, n_tiles: int = 0, block_rows: int = 8,
                       span: int = 0, rownnz_b=None):
    """Dense-SPA kernel numeric phase + XLA compaction — same output
    contract as :func:`spgemm_numeric` (col/row_nnz/overflow identical,
    values to float tolerance).  ``n_tiles·tile_n`` must bound every row's
    product-column extent; the default tiles the planner's ``span`` bound
    (the banded/FEM lever), or the full column space when no span is
    known."""
    from repro.core.spgemm import compact_dense
    if tile_n <= 0:
        from repro.core.binning import spa_tile, DEFAULT_LANE_BUDGET
        tile_n, n_tiles = spa_tile(min(span, b.ncols) if span else b.ncols,
                                   DEFAULT_LANE_BUDGET)
    acc, pres, lo = _acc_k.spa_numeric_pallas(
        a.rpt, a.col, a.val, b.rpt, b.col, b.val, rows,
        max_deg_a=max_deg_a, max_deg_b=max_deg_b, ncols_b=b.ncols,
        tile_n=tile_n, n_tiles=n_tiles, block_rows=block_rows,
        interpret=_interpret(), rownnz_b=rownnz_b)
    return compact_dense(acc, pres.astype(bool), row_capacity, col_offset=lo)


def spgemm_numeric_routed(a: CSRDevice, b: CSRDevice, rows: jax.Array, *,
                          max_deg_a: int, max_deg_b: int, row_capacity: int,
                          block_rows: int = 8, route: str = ROUTE_ESC,
                          tile_n: int = 0, n_tiles: int = 0, span: int = 0,
                          rownnz_b=None):
    """Route-dispatched numeric phase — ``spgemm_binned``'s per-bucket
    kernel entry point."""
    _check_route(route)
    if route == ROUTE_SPA:
        return spgemm_numeric_spa(
            a, b, rows, max_deg_a=max_deg_a, max_deg_b=max_deg_b,
            row_capacity=row_capacity, tile_n=tile_n, n_tiles=n_tiles,
            block_rows=block_rows, span=span, rownnz_b=rownnz_b)
    return spgemm_numeric(a, b, rows, max_deg_a=max_deg_a,
                          max_deg_b=max_deg_b, row_capacity=row_capacity,
                          block_rows=block_rows, rownnz_b=rownnz_b)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    return _fa_k.flash_attention(q, k, v, causal=causal, block_q=block_q,
                                 block_k=block_k, interpret=_interpret())
