"""Bitonic sorting network on the last axis — Pallas-compatible.

The paper's Algorithm 2 counts distinct output columns with a per-thread hash
table (linear probing, data-dependent `while`).  On TPU that serializes on the
scalar core, so we replace it with a bitonic network (DESIGN.md §3): every
compare-exchange stage is a static reshape + min/max/where — pure VPU work,
no gathers, no data-dependent control flow.  Usable both inside ``pallas_call``
kernel bodies and as plain jnp (the ref path).

Last-axis length must be a power of two; pad with ``COL_SENTINEL`` (sorts to
the tail) before calling.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


def pad_to_pow2(keys: jnp.ndarray, vals: jnp.ndarray | None, fill_key):
    """Pad the last axis to the next power of two with ``fill_key`` (vals
    padded with 0), ready for :func:`bitonic_sort_pairs`.

    When the length is already a power of two the inputs are returned
    unchanged: the ``full().at[..., :f].set`` pattern would otherwise
    const-fold a zero-width remainder into an empty captured constant, which
    ``pallas_call`` rejects — and pow2 widths are the common case under the
    degree-binned pipeline.
    """
    f = keys.shape[-1]
    f2 = next_pow2(f)
    if f2 == f:
        return keys, vals
    kbuf = jnp.full(keys.shape[:-1] + (f2,), fill_key, keys.dtype)
    kbuf = kbuf.at[..., :f].set(keys)
    if vals is None:
        return kbuf, None
    vbuf = jnp.zeros(vals.shape[:-1] + (f2,), vals.dtype)
    return kbuf, vbuf.at[..., :f].set(vals)


def _stage_masks(n: int, k: int, j: int) -> jnp.ndarray:
    """Ascending-direction mask for stage (k, j), shape (n//(2s), s).

    Built from ``broadcasted_iota`` (traced, not a captured constant — Pallas
    kernels may not close over host arrays).  Partners differ only in bit
    j < k, so bit k is shared between the two slots: slot 0's index suffices.
    """
    s = 1 << j
    m_idx = jax.lax.broadcasted_iota(jnp.int32, (n // (2 * s), s), 0)
    r_idx = jax.lax.broadcasted_iota(jnp.int32, (n // (2 * s), s), 1)
    i0 = m_idx * (2 * s) + r_idx
    return ((i0 >> k) & 1) == 0


def bitonic_sort(keys: jnp.ndarray) -> jnp.ndarray:
    """Sort ``keys`` ascending along the last axis (power-of-two length)."""
    out, _ = bitonic_sort_pairs(keys, None)
    return out


def bitonic_sort_pairs(keys: jnp.ndarray, vals: jnp.ndarray | None):
    """Sort keys ascending, carrying ``vals`` through the same permutation."""
    n = keys.shape[-1]
    assert _is_pow2(n), f"bitonic length {n} not a power of two"
    log_n = n.bit_length() - 1
    lead = keys.shape[:-1]
    for k in range(1, log_n + 1):
        for j in range(k - 1, -1, -1):
            s = 1 << j
            up = _stage_masks(n, k, j)
            kk = keys.reshape(lead + (n // (2 * s), 2, s))
            a, b = kk[..., 0, :], kk[..., 1, :]
            do_swap = jnp.where(up, a > b, a < b)
            a2 = jnp.where(do_swap, b, a)
            b2 = jnp.where(do_swap, a, b)
            keys = jnp.concatenate(
                [a2[..., None, :], b2[..., None, :]], axis=-2).reshape(lead + (n,))
            if vals is not None:
                vv = vals.reshape(lead + (n // (2 * s), 2, s))
                va, vb = vv[..., 0, :], vv[..., 1, :]
                va2 = jnp.where(do_swap, vb, va)
                vb2 = jnp.where(do_swap, va, vb)
                vals = jnp.concatenate(
                    [va2[..., None, :], vb2[..., None, :]], axis=-2).reshape(lead + (n,))
    return keys, vals


def segmented_run_sums(sorted_keys: jnp.ndarray, vals: jnp.ndarray,
                       sentinel) -> tuple[jnp.ndarray, jnp.ndarray]:
    """For runs of equal keys in a sorted buffer, place the run's value-sum at
    the run's FIRST slot (other slots keep partial sums; mask with ``first``).

    Log-step segmented suffix-scan: static shifts only (Pallas-safe).
    Returns (first_mask, run_sums_at_first).
    """
    n = sorted_keys.shape[-1]
    acc = vals
    shift = 1
    while shift < n:
        shifted_acc = jnp.concatenate(
            [acc[..., shift:], jnp.zeros_like(acc[..., :shift])], axis=-1)
        shifted_key = jnp.concatenate(
            [sorted_keys[..., shift:],
             jnp.full_like(sorted_keys[..., :shift], sentinel)], axis=-1)
        same = (shifted_key == sorted_keys) & (sorted_keys != sentinel)
        acc = acc + jnp.where(same, shifted_acc, 0.0)
        shift *= 2
    prev = jnp.concatenate(
        [jnp.full_like(sorted_keys[..., :1], sentinel), sorted_keys[..., :-1]],
        axis=-1)
    first = (sorted_keys != prev) & (sorted_keys != sentinel)
    return first, acc
