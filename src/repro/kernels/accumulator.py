"""Pallas kernels: the accumulator (SPA) route of the hybrid backend.

DESIGN.md §5: per-bucket routing replaces sort-everything.  The ESC kernels
(``spgemm_symbolic`` / ``spgemm_numeric``) pay O(w·log²w) bitonic stages per
expanded ``(rows, w)`` buffer; when B's column space is compact a dense
accumulator does the same work in O(w + N) lane-ops with no sort:

  * symbolic — **bitmask popcount**: pack B's column space into
    ``ceil(N/32)`` uint32 word lanes per row, OR each gathered product
    column's bit in (broadcast-compare + log-tree OR: static shapes, no
    scatter, VPU-only), then popcount → exact distinct count ``z*``;
  * numeric — **dense SPA**: one-hot-accumulate value products into a
    ``(block_rows, tile_n)`` dense accumulator (column-tiled over a second
    grid axis when ``next_pow2(ncols_b)`` exceeds the VMEM lane budget),
    track structural presence separately, and let the caller compact into
    the predicted ``row_capacity`` slots (``core.spgemm.compact_dense``).

Both kernels share the product gather of the ESC kernels, so z*/f* equal the
sort path bit for bit (distinct counts are order-invariant) and the numeric
outputs match to float tolerance with identical ``row_nnz``/overflow.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.csr import COL_SENTINEL, pad_row_ids
from .sortnet import next_pow2, pad_to_pow2

# Cap on the broadcast-compare intermediate (rows·chunk·lanes elements) —
# keeps the 3D one-hot tensors a few MB of VMEM; wider buffers fall back to
# chunked accumulation over the product axis.
_CHUNK_ELEMS = 1 << 21


def _popcount32(v: jax.Array) -> jax.Array:
    """Per-lane population count of a uint32 array (SWAR bit-twiddle —
    static shifts/masks only, Pallas-safe on backends without a native op)."""
    v = v - ((v >> jnp.uint32(1)) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> jnp.uint32(2)) & jnp.uint32(0x33333333))
    v = (v + (v >> jnp.uint32(4))) & jnp.uint32(0x0F0F0F0F)
    return ((v * jnp.uint32(0x01010101)) >> jnp.uint32(24)).astype(jnp.int32)


def _or_fold(x: jax.Array) -> jax.Array:
    """Bitwise-OR reduction over a pow2-sized axis 1 via log-step halving
    (static reshapes, no data-dependent control flow)."""
    while x.shape[1] > 1:
        h = x.shape[1] // 2
        x = x.reshape(x.shape[0], h, 2, *x.shape[2:])
        x = x[:, :, 0] | x[:, :, 1]
    return x[:, 0]


def _chunk_of(rows: int, lanes: int, width: int) -> int:
    """Largest pow2 chunk of the product axis keeping rows·chunk·lanes small."""
    chunk = width
    while rows * chunk * lanes > _CHUNK_ELEMS and chunk > 1:
        chunk //= 2
    return chunk


def _gather_block(rows, row_ok, a_rpt_ref, a_col_ref, b_rpt_ref, b_col_ref,
                  rownnz_b_ref, max_deg_a: int, max_deg_b: int,
                  a_val_ref=None, b_val_ref=None):
    """The shared in-kernel product gather (mirrors the ESC kernels).

    Returns ``(cols (BS, DA·DB), vals|None, deg_b (BS, DA))`` — rows with
    ``row_ok`` False (block padding) gather nothing.
    """
    bs = rows.shape[0]
    deg_a = a_rpt_ref[rows + 1] - a_rpt_ref[rows]
    ia = jax.lax.broadcasted_iota(jnp.int32, (bs, max_deg_a), 1)
    idx_a = jnp.clip(a_rpt_ref[rows][:, None] + ia, 0, a_col_ref.shape[0] - 1)
    valid_a = row_ok[:, None] & (ia < deg_a[:, None])
    ks = jnp.where(valid_a, a_col_ref[idx_a], 0)

    deg_b = jnp.where(valid_a, rownnz_b_ref[ks], 0)
    ib = jax.lax.broadcasted_iota(jnp.int32, (bs, max_deg_a, max_deg_b), 2)
    idx_b = jnp.clip(b_rpt_ref[ks][:, :, None] + ib, 0, b_col_ref.shape[0] - 1)
    valid = valid_a[:, :, None] & (ib < deg_b[:, :, None])
    cols = jnp.where(valid, b_col_ref[idx_b], COL_SENTINEL)
    vals = None
    if a_val_ref is not None:
        av = jnp.where(valid_a, a_val_ref[idx_a], 0.0)
        vals = jnp.where(valid, av[:, :, None] * b_val_ref[idx_b], 0.0)
    f = max_deg_a * max_deg_b
    cols = cols.reshape(bs, f)
    if vals is not None:
        vals = vals.reshape(bs, f)
    return cols, vals, deg_b


def extent_relative(cols: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Shift each row's columns to its own minimum: ``(rel_cols, lo)``.

    The planner bounds every bucket row's product-column *extent*
    (``RowBucket.span``), so the bitmask words / dense tile only need to
    cover that extent, not B's full column space — the lever that makes the
    SPA route O(w + extent) instead of O(w + N) on banded/FEM structure.
    Sentinel padding stays sentinel (never lands in any window; rows with no
    products keep an all-sentinel buffer and get offset 0).  THE definition
    of the relative-addressing contract — shared by the Pallas kernels and
    the jnp SPA paths in ``core.spgemm`` so they cannot diverge."""
    lo = jnp.min(cols, axis=-1)                           # sentinel if empty
    rel = jnp.where(cols == COL_SENTINEL, COL_SENTINEL, cols - lo[:, None])
    return rel, jnp.where(lo == COL_SENTINEL, 0, lo)


def _rel_cols(cols: jax.Array) -> jax.Array:
    return extent_relative(cols)[0]


def bitmask_distinct(cols: jax.Array, n_words: int) -> jax.Array:
    """Distinct count per row of a sentinel-padded column buffer.

    Broadcast-compare each product column's bit into its extent-relative
    word lane, log-tree OR over the product axis, popcount the packed
    bitmask.  O(w·span/32) lane cost with no sort — the replacement for
    bitonic + adjacent-unique wherever the extent is narrow.  Sentinel slots
    target word ``2^26``-ish and never match.  Pure jnp (static shapes, no
    scatter): runs inside Pallas kernel bodies AND as the SPA route's jnp
    path (``core.predictor.count_distinct_dense``).
    """
    bs = cols.shape[0]
    colsp, _ = pad_to_pow2(cols, None, COL_SENTINEL)
    rel = _rel_cols(colsp)
    w2 = colsp.shape[1]
    word = rel >> 5                                       # (BS, W2)
    bitval = jnp.uint32(1) << (rel & 31).astype(jnp.uint32)
    chunk = _chunk_of(bs, n_words, w2)
    mask = jnp.zeros((bs, n_words), jnp.uint32)
    for c0 in range(0, w2, chunk):
        wd = word[:, c0:c0 + chunk]
        bv = bitval[:, c0:c0 + chunk]
        iota_w = jax.lax.broadcasted_iota(jnp.int32,
                                          (bs, wd.shape[1], n_words), 2)
        contrib = jnp.where(wd[:, :, None] == iota_w, bv[:, :, None],
                            jnp.uint32(0))
        mask = mask | _or_fold(contrib)
    return _popcount32(mask).sum(axis=-1)


def _bitmask_kernel(rows_ref, a_rpt_ref, a_col_ref, b_rpt_ref, b_col_ref,
                    rownnz_b_ref, z_ref, f_ref, *, block_samples: int,
                    max_deg_a: int, max_deg_b: int, n_words: int,
                    n_valid: int):
    i = pl.program_id(0)
    pos = i * block_samples + jax.lax.broadcasted_iota(
        jnp.int32, (block_samples,), 0)
    row_ok = pos < n_valid            # block-padding rows contribute nothing
    rows = rows_ref[...]
    cols, _, deg_b = _gather_block(rows, row_ok, a_rpt_ref, a_col_ref,
                                   b_rpt_ref, b_col_ref, rownnz_b_ref,
                                   max_deg_a, max_deg_b)
    z_ref[...] = bitmask_distinct(cols, n_words).sum(keepdims=True)
    f_ref[...] = deg_b.astype(jnp.int32).sum(axis=-1).sum(keepdims=True)


def _fused_bitmask_kernel(rows_ref, a_rpt_ref, a_col_ref, b_rpt_ref,
                          b_col_ref, rownnz_b_ref, z_ref, f_ref, flop_ref, *,
                          block_samples: int, max_deg_a: int, max_deg_b: int,
                          n_words: int, n_valid: int):
    """Fused Algorithm 1 + bitmask Algorithm 2 — the SPA twin of
    ``spgemm_symbolic._fused_kernel`` (same outputs, no sort)."""
    i = pl.program_id(0)
    pos = i * block_samples + jax.lax.broadcasted_iota(
        jnp.int32, (block_samples,), 0)
    row_ok = pos < n_valid
    rows = rows_ref[...]
    cols, _, deg_b = _gather_block(rows, row_ok, a_rpt_ref, a_col_ref,
                                   b_rpt_ref, b_col_ref, rownnz_b_ref,
                                   max_deg_a, max_deg_b)
    flop = deg_b.sum(axis=-1).astype(jnp.int32)           # (BS,)
    z_ref[...] = bitmask_distinct(cols, n_words).sum(keepdims=True)
    f_ref[...] = flop.sum(keepdims=True)
    flop_ref[...] = flop


def _symbolic_call(kernel, outs, a_rpt, a_col, b_rpt, b_col, rows, *,
                   max_deg_a, max_deg_b, ncols_b, span, block_samples,
                   interpret, rownnz_b):
    s = rows.shape[0]
    nblocks = -(-s // block_samples)
    rows_p = pad_row_ids(rows, block_samples)
    if rownnz_b is None:
        rownnz_b = jnp.diff(b_rpt)
    span = int(min(span, ncols_b) if span else ncols_b)
    n_words = -(-span // 32)
    out_specs = [pl.BlockSpec((1,), lambda i: (i,)),
                 pl.BlockSpec((1,), lambda i: (i,))]
    out_shape = [jax.ShapeDtypeStruct((nblocks,), jnp.int32),
                 jax.ShapeDtypeStruct((nblocks,), jnp.int32)]
    if outs == 3:
        out_specs.append(pl.BlockSpec((block_samples,), lambda i: (i,)))
        out_shape.append(jax.ShapeDtypeStruct((nblocks * block_samples,),
                                              jnp.int32))
    return pl.pallas_call(
        functools.partial(kernel, block_samples=block_samples,
                          max_deg_a=max_deg_a, max_deg_b=max_deg_b,
                          n_words=n_words, n_valid=s),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block_samples,), lambda i: (i,)),  # rows: blocked
            pl.BlockSpec(memory_space=pl.ANY),               # a_rpt
            pl.BlockSpec(memory_space=pl.ANY),               # a_col
            pl.BlockSpec(memory_space=pl.ANY),               # b_rpt
            pl.BlockSpec(memory_space=pl.ANY),               # b_col
            pl.BlockSpec(memory_space=pl.ANY),               # rownnz_b
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(rows_p, a_rpt, a_col, b_rpt, b_col, rownnz_b)


@functools.partial(jax.jit, static_argnames=(
    "max_deg_a", "max_deg_b", "ncols_b", "span", "block_samples",
    "interpret"))
def bitmask_symbolic_pallas(a_rpt, a_col, b_rpt, b_col, rows, *,
                            max_deg_a: int, max_deg_b: int, ncols_b: int,
                            span: int = 0, block_samples: int = 8,
                            interpret: bool = True, rownnz_b=None):
    """(z*, f*) via bitmask popcount — bit-equal to the sort kernel.

    ``span`` is the planner's bound on per-row product-column extent
    (``RowBucket.span``); 0 falls back to the full column space."""
    z_b, f_b = _symbolic_call(_bitmask_kernel, 2, a_rpt, a_col, b_rpt, b_col,
                              rows, max_deg_a=max_deg_a, max_deg_b=max_deg_b,
                              ncols_b=ncols_b, span=span,
                              block_samples=block_samples,
                              interpret=interpret, rownnz_b=rownnz_b)
    return z_b.sum(), f_b.sum()


@functools.partial(jax.jit, static_argnames=(
    "max_deg_a", "max_deg_b", "ncols_b", "span", "block_samples",
    "interpret"))
def fused_flop_symbolic_bitmask_pallas(a_rpt, a_col, b_rpt, b_col, rows, *,
                                       max_deg_a: int, max_deg_b: int,
                                       ncols_b: int, span: int = 0,
                                       block_samples: int = 8,
                                       interpret: bool = True, rownnz_b=None):
    """One pallas_call → (z*, f*, flop-per-sampled-row) — the SPA route of
    the binned predictor's fused per-bucket invocation."""
    s = rows.shape[0]
    z_b, f_b, flop = _symbolic_call(
        _fused_bitmask_kernel, 3, a_rpt, a_col, b_rpt, b_col, rows,
        max_deg_a=max_deg_a, max_deg_b=max_deg_b, ncols_b=ncols_b, span=span,
        block_samples=block_samples, interpret=interpret, rownnz_b=rownnz_b)
    return z_b.sum(), f_b.sum(), flop[:s]


def _spa_numeric_kernel(rows_ref, a_rpt_ref, a_col_ref, a_val_ref, b_rpt_ref,
                        b_col_ref, b_val_ref, rownnz_b_ref, acc_ref, pres_ref,
                        lo_ref, *, block_rows: int, max_deg_a: int,
                        max_deg_b: int, tile_n: int):
    """Grid step (i, t): one-hot-accumulate row block ``i``'s value products
    into extent-relative dense column tile ``t`` — values and structural
    presence separately (a cancellation summing to 0.0 is still an output
    entry, as on ESC).  Per-row column offsets come out in ``lo`` so the
    caller's compaction can restore absolute column ids."""
    rows = rows_ref[...]
    row_ok = jnp.ones((block_rows,), jnp.bool_)   # pads handled by the caller
    cols, vals, _ = _gather_block(rows, row_ok, a_rpt_ref, a_col_ref,
                                  b_rpt_ref, b_col_ref, rownnz_b_ref,
                                  max_deg_a, max_deg_b,
                                  a_val_ref=a_val_ref, b_val_ref=b_val_ref)
    colsp, valsp = pad_to_pow2(cols, vals, COL_SENTINEL)
    rel, lo = extent_relative(colsp)
    w2 = colsp.shape[1]
    col0 = pl.program_id(1) * tile_n
    chunk = _chunk_of(block_rows, tile_n, w2)
    acc = jnp.zeros((block_rows, tile_n), jnp.float32)
    pres = jnp.zeros((block_rows, tile_n), jnp.bool_)
    for c0 in range(0, w2, chunk):
        c = rel[:, c0:c0 + chunk]
        v = valsp[:, c0:c0 + chunk]
        iota_n = col0 + jax.lax.broadcasted_iota(
            jnp.int32, (block_rows, c.shape[1], tile_n), 2)
        hit = c[:, :, None] == iota_n                     # (BS, chunk, TN)
        acc = acc + jnp.where(hit, v[:, :, None], 0.0).sum(axis=1)
        pres = pres | hit.any(axis=1)
    acc_ref[...] = acc
    pres_ref[...] = pres.astype(jnp.int32)
    lo_ref[...] = lo


@functools.partial(jax.jit, static_argnames=(
    "max_deg_a", "max_deg_b", "ncols_b", "tile_n", "n_tiles", "block_rows",
    "interpret"))
def spa_numeric_pallas(a_rpt, a_col, a_val, b_rpt, b_col, b_val, rows, *,
                       max_deg_a: int, max_deg_b: int, ncols_b: int,
                       tile_n: int, n_tiles: int = 0, block_rows: int = 8,
                       interpret: bool = True, rownnz_b=None):
    """Dense accumulator + presence + per-row column offsets for ``rows``:
    ``(acc, present, lo)`` with ``acc``/``present`` of shape
    ``(R, n_tiles·tile_n)`` covering each row's product-column extent
    relative to its own minimum column ``lo``; compaction into the predicted
    capacities is the cheap XLA pass ``core.spgemm.compact_dense`` (the same
    kernel/XLA split as the ESC numeric path).

    ``n_tiles·tile_n`` must bound every row's column extent — the planner
    guarantees that for bucket calls (``RowBucket.span``); the default
    ``n_tiles`` covers the full column space, which is always safe."""
    r = rows.shape[0]
    nblocks = -(-r // block_rows)
    pad_r = nblocks * block_rows
    rows_p = pad_row_ids(rows, block_rows)
    if rownnz_b is None:
        rownnz_b = jnp.diff(b_rpt)
    if n_tiles <= 0:
        n_tiles = -(-int(ncols_b) // tile_n)
    acc, pres, lo = pl.pallas_call(
        functools.partial(_spa_numeric_kernel, block_rows=block_rows,
                          max_deg_a=max_deg_a, max_deg_b=max_deg_b,
                          tile_n=tile_n),
        grid=(nblocks, n_tiles),
        in_specs=[
            pl.BlockSpec((block_rows,), lambda i, t: (i,)),
            pl.BlockSpec(memory_space=pl.ANY),               # a_rpt
            pl.BlockSpec(memory_space=pl.ANY),               # a_col
            pl.BlockSpec(memory_space=pl.ANY),               # a_val
            pl.BlockSpec(memory_space=pl.ANY),               # b_rpt
            pl.BlockSpec(memory_space=pl.ANY),               # b_col
            pl.BlockSpec(memory_space=pl.ANY),               # b_val
            pl.BlockSpec(memory_space=pl.ANY),               # rownnz_b
        ],
        out_specs=[pl.BlockSpec((block_rows, tile_n), lambda i, t: (i, t)),
                   pl.BlockSpec((block_rows, tile_n), lambda i, t: (i, t)),
                   pl.BlockSpec((block_rows,), lambda i, t: (i,))],
        out_shape=[
            jax.ShapeDtypeStruct((pad_r, n_tiles * tile_n), jnp.float32),
            jax.ShapeDtypeStruct((pad_r, n_tiles * tile_n), jnp.int32),
            jax.ShapeDtypeStruct((pad_r,), jnp.int32),
        ],
        interpret=interpret,
    )(rows_p, a_rpt, a_col, a_val, b_rpt, b_col, b_val, rownnz_b)
    return acc[:r], pres[:r], lo[:r]
