"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.csr import CSRDevice, COL_SENTINEL
from repro.core import flop as flop_mod
from repro.core import predictor as pred_mod
from repro.core import spgemm as spgemm_mod


def flop_per_row_ref(a_rpt, a_col, rownnz_b):
    """Oracle for kernels.flop_per_row (thin shim over core.flop)."""
    m = a_rpt.shape[0] - 1
    cap = a_col.shape[0]
    k = rownnz_b.shape[0]
    a = CSRDevice(rpt=a_rpt, col=a_col, val=jnp.zeros(cap, jnp.float32),
                  shape=(m, k))
    b_rpt = jnp.concatenate([jnp.zeros(1, jnp.int32),
                             jnp.cumsum(rownnz_b).astype(jnp.int32)])
    b = CSRDevice(rpt=b_rpt, col=jnp.zeros(1, jnp.int32),
                  val=jnp.zeros(1, jnp.float32), shape=(k, 1))
    floprc, _ = flop_mod.flop_per_row(a, b)
    return floprc


def sampled_symbolic_ref(a: CSRDevice, b: CSRDevice, rows, max_deg_a, max_deg_b):
    """Oracle for kernels.spgemm_symbolic: (z*, f*)."""
    cols, valid = pred_mod.gather_sampled_products(a, b, rows, max_deg_a, max_deg_b)
    z = pred_mod.count_distinct_sorted(cols).sum()
    f = valid.sum()
    return z, f


def fused_flop_symbolic_ref(a: CSRDevice, b: CSRDevice, rows, max_deg_a,
                            max_deg_b):
    """Oracle for kernels.fused_flop_symbolic: (z*, f*, flop per sampled row)."""
    cols, valid = pred_mod.gather_sampled_products(a, b, rows, max_deg_a, max_deg_b)
    z = pred_mod.count_distinct_sorted(cols).sum()
    flop = valid.sum(axis=-1).astype(jnp.int32)
    return z, flop.sum(), flop


def flop_rows_ref(a: CSRDevice, b: CSRDevice, rows):
    """Oracle for kernels.flop_rows: full jnp flop, gathered at ``rows``."""
    floprc, _ = flop_mod.flop_per_row(a, b)
    return floprc[rows]


def spgemm_numeric_ref(a: CSRDevice, b: CSRDevice, rows, max_deg_a, max_deg_b,
                       row_capacity):
    """Oracle for kernels.spgemm_numeric (+compact): per-row CSR-ish output."""
    cols, vals, _ = spgemm_mod.gather_products(a, b, rows, max_deg_a, max_deg_b)
    return spgemm_mod._accumulate_block(cols, vals, row_capacity)


def bitmask_symbolic_ref(a: CSRDevice, b: CSRDevice, rows, max_deg_a,
                         max_deg_b):
    """Oracle for kernels.bitmask_symbolic: dense-presence distinct count.

    Counts are a property of the column *set*, so this equals
    ``sampled_symbolic_ref`` bit for bit — the SPA-vs-ESC symbolic
    equivalence contract (DESIGN.md §5)."""
    cols, valid = pred_mod.gather_sampled_products(a, b, rows, max_deg_a,
                                                   max_deg_b)
    z = pred_mod.count_distinct_dense(cols, b.ncols).sum()
    return z, valid.sum()


def spa_numeric_ref(a: CSRDevice, b: CSRDevice, rows, max_deg_a, max_deg_b,
                    row_capacity):
    """Oracle for kernels.spgemm_numeric_spa: dense scatter-add + compact."""
    cols, vals, _ = spgemm_mod.gather_products(a, b, rows, max_deg_a,
                                               max_deg_b)
    return spgemm_mod._dense_accumulate_block(cols, vals, b.ncols,
                                              row_capacity)


def attention_ref(q, k, v, *, causal: bool = True):
    """Oracle for kernels.flash_attention: dense softmax attention, fp32."""
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    kf = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    qf = q.astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) / (d ** 0.5)
    if causal:
        sk = k.shape[2]
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)
