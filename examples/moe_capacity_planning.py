"""Beyond-paper example: sizing MoE dispatch with the paper's sampling ideas
(DESIGN §4), two levels:

  1. block-sparse buffer TOTAL via the sampled compression ratio — the
     paper's eq. 4 verbatim on the (group × expert) dispatch structure;
  2. per-expert token-slot capacity via sampled-group load measurement —
     replacing the blind ``capacity_factor`` guess.

Demonstrated on a SKEWED router (the realistic failure case for fixed
capacity factors), verifying near-zero drops at the predicted capacity.

Run:  PYTHONPATH=src python examples/moe_capacity_planning.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.core import moe_capacity
from repro.models import moe as moe_mod
from repro.models.schema import init_params

cfg = get_smoke_config("deepseek-v3-671b")
E, K = cfg.moe_num_experts, cfg.moe_top_k
B, S = 32, 512

params = init_params(moe_mod.moe_schema(cfg), jax.random.PRNGKey(0),
                     jnp.float32)
# skew the router: two experts get a strong prior (hot-expert pathology)
router = np.array(params["router"])          # writable copy
router[:, :2] += 0.35
params["router"] = jnp.asarray(router)

rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
logits = np.asarray((x @ params["router"]).astype(jnp.float32))
ids = np.argsort(-logits, axis=-1)[..., :K].reshape(B * S, K)

# ---- level 1: block-sparse buffer total (paper eq. 4 on the dispatch) ----
plan = moe_capacity.predict_dispatch_capacity(ids, E, group_size=64, seed=0,
                                              sample_fraction=0.05)
exact = moe_capacity.exact_dispatch_blocks(ids, group_size=64)
print(f"experts={E} top-{K} tokens={B*S} (skewed router)")
print(f"blocks: exact={exact:,} predicted={plan.predicted_blocks:,.0f} "
      f"({(plan.predicted_blocks-exact)/exact*100:+.2f}%)  "
      f"CR*={plan.compression_ratio:.2f}")

# ---- level 2: per-expert slot capacity from sampled groups ----
pred_cap = moe_capacity.predict_group_capacity(ids, E, group_size=S,
                                               sample_fraction=0.2, seed=1)
guess_cap = moe_mod.default_capacity(cfg, S)   # blind capacity_factor guess
y1, aux1 = moe_mod.apply_moe(params, cfg, x, capacity=guess_cap)
y2, aux2 = moe_mod.apply_moe(params, cfg, x, capacity=pred_cap)
print(f"capacity: blind cf-guess={guess_cap} → dropped "
      f"{float(aux1.dropped_fraction)*100:.2f}% of assignments")
print(f"capacity: sampled-predicted={pred_cap} → dropped "
      f"{float(aux2.dropped_fraction)*100:.2f}%")
print(f"true upper bound (never-drop guess) would be {S*K} slots/expert "
      f"({S*K//pred_cap}× the predicted size)")
assert float(aux2.dropped_fraction) < 0.01
print("OK — predicted capacity holds the skewed routing with <1% drops.")
