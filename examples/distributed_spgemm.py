"""Distributed SpGEMM through the unified plan/execute pipeline (DESIGN §6):
sample → predict (binned, routed) → partition on predicted nnz →
per-bucket-per-shard capacities → binned routed kernels under shard_map —
plus the signature-keyed plan cache serving a repeated same-structure
multiply with zero retraces, the pow2-quantized cache key sharing
executables across same-family matrices, and the overflow re-planning loop
recovering from a deliberately under-allocated plan (DESIGN §7).

Uses 4 placeholder devices (works on any machine); the same code drives the
`data` axis of the production mesh.

Run:  PYTHONPATH=src python examples/distributed_spgemm.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import numpy as np

from repro.sparse import random as sprand
from repro.sparse.formats import CSR, spgemm_dense_oracle
from repro.core import plan as plan_mod
from repro.core import oracle, partition

# a matrix with strongly varying row compression — the case where
# FLOP-balanced sharding mis-loads devices
a = sprand.banded(2000, 2000, 36, 28, seed=1)      # heavy, high-CR rows
b = sprand.banded(2000, 2000, 12, 40, seed=2)

mesh = jax.make_mesh((4,), ("data",))
plan = plan_mod.plan_spgemm(a, b, mesh=mesh)
flopr, _ = oracle.flop_per_row(a, b)

print(f"predicted NNZ(C) = {plan.predicted_nnz:,.0f}; "
      f"max bucket capacity {plan.alloc.row_capacity} "
      f"(upper bound {int(flopr.max())}); "
      f"{plan.shard_slots():,} output slots per shard")
print(f"predicted-NNZ-balanced imbalance: {plan.partition.imbalance:.3f}")
p_flop = partition.balanced_contiguous(flopr, 4)
nnzr, z = oracle.exact_structure(a, b)
w = np.add.reduceat(nnzr, p_flop.bounds[:-1])
print(f"FLOP-balanced imbalance on true work: {w.max()/w.mean():.3f}")

out = plan_mod.execute(plan, a, b)
print(f"per-shard overflow: {out.shard_overflow.tolist()}")
c = plan_mod.reassemble(plan, out)
err = np.abs(c.to_dense() - spgemm_dense_oracle(a, b)).max()
print(f"4-shard numeric phase: nnz={c.nnz:,} (exact {z:,}), max err={err:.2e}")
assert err < 1e-3 and c.nnz == z

# serving: same sparsity structure, new values — the plan cache hands back
# the compiled executable, zero retraces
rng = np.random.default_rng(7)
a2 = CSR(rpt=a.rpt.copy(), col=a.col.copy(),
         val=rng.standard_normal(a.nnz).astype(np.float32), shape=a.shape)
traces_before = plan_mod.plan_cache().stats()["traces"]
plan2 = plan_mod.plan_spgemm(a2, b, mesh=mesh)
c2 = plan_mod.reassemble(plan2, plan_mod.execute(plan2, a2, b))
stats = plan_mod.plan_cache().stats()
err2 = np.abs(c2.to_dense() - spgemm_dense_oracle(a2, b)).max()
assert err2 < 1e-3 and stats["traces"] == traces_before
print(f"repeat multiply (new values): max err={err2:.2e}, "
      f"cache {stats['hits']} hit(s), {stats['traces'] - traces_before} "
      "retraces")

# quantized plan cache: a same-family matrix pair from DIFFERENT seeds lands
# on the same pow2-padded plan key and reuses the compiled executables
a3 = sprand.banded(2000, 2000, 36, 28, seed=11)
b3 = sprand.banded(2000, 2000, 12, 40, seed=12)
cache = plan_mod.PlanCache()
q1 = plan_mod.plan_spgemm(a, b, mesh=mesh, pop_quant=True)
plan_mod.execute(q1, a, b, cache=cache)
tq = cache.stats()["traces"]
q2 = plan_mod.plan_spgemm(a3, b3, mesh=mesh, pop_quant=True)
c3 = plan_mod.reassemble(q2, plan_mod.execute(q2, a3, b3, cache=cache))
assert q2.key == q1.key and cache.stats()["traces"] == tq
print(f"quantized cache, different-seed pair: same key, "
      f"{cache.stats()['traces'] - tq} retraces, "
      f"row padding {q2.stats()['row_padding']}x "
      f"(err {np.abs(c3.to_dense() - spgemm_dense_oracle(a3, b3)).max():.2e})")

# overflow re-planning: plan with NO safety margin — the numeric phase
# under-allocates, the armed retry loop bumps only the overflowing buckets
# (pow2-rounded) and re-executes them; the result is still exact
p_tight = plan_mod.plan_spgemm(a, b, mesh=mesh, safety=0.0, retry_safety=1.5)
res = plan_mod.execute(p_tight, a, b)
c4 = plan_mod.reassemble(p_tight, res)
err4 = np.abs(c4.to_dense() - spgemm_dense_oracle(a, b)).max()
assert err4 < 1e-3 and int(res.shard_overflow.sum()) == 0
print(f"re-planning loop: {p_tight.retries} round(s), "
      f"{len(p_tight.retry_events)} bucket(s) bumped to "
      f"{[t.capacity for t in p_tight.shard_tables]} slots, max err={err4:.2e}")

# column-partitioned B (DESIGN §8): the 4 devices fold into 2 row shards ×
# 2 column panels — each device receives ONLY the gathered panel entries
# its rows reference, instead of a full replica of B
p_pan = plan_mod.plan_spgemm(a, b, mesh=mesh, n_panels=2)
res_pan = plan_mod.execute(p_pan, a, b)
c5 = plan_mod.reassemble(p_pan, res_pan)
err5 = np.abs(c5.to_dense() - spgemm_dense_oracle(a, b)).max()
comm = p_pan.comm_stats()
assert err5 < 1e-3 and int(res_pan.shard_overflow.sum()) == 0
print(f"column-partitioned B ({comm['n_panels']} panels × "
      f"{comm['row_shards']} row shards): per-device B "
      f"{comm['per_device_b_bytes']:,} B vs {comm['replicated_b_bytes']:,} B "
      f"replicated ({comm['footprint_reduction']}x smaller), max "
      f"err={err5:.2e}")

# automatic template selection: no handle to hold — the registry resolves
# each member's structural sketch to the family template
reg = plan_mod.TemplateRegistry()
for seed in (21, 22, 23):
    aa = sprand.banded(2000, 2000, 36, 28, seed=seed)
    pauto = plan_mod.plan_spgemm(aa, b, template="auto", registry=reg)
    plan_mod.execute(pauto, aa, b)
print(f"auto templates: {reg.stats()['misses']} template(s) for "
      f"{reg.stats()['hits'] + reg.stats()['misses']} members "
      f"({reg.stats()['hits']} registry hits)")
print("OK — sharded SpGEMM exact, balanced, within predicted buffers, "
      "cache-served; quantized keys shared across seeds; overflow healed "
      "by re-planning; B panel-gathered instead of replicated; templates "
      "auto-selected.")
