"""Distributed SpGEMM across a device mesh, load-balanced by the paper's
predicted output structure (DESIGN §3: thread-level binning → shard-level
partitioning).

Uses 4 placeholder devices (works on any machine); the same code drives the
`data` axis of the production mesh.

Run:  PYTHONPATH=src python examples/distributed_spgemm.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import numpy as np

from repro.sparse import random as sprand
from repro.sparse.formats import spgemm_dense_oracle
from repro.core import distributed, oracle, partition

# a matrix with strongly varying row compression — the case where
# FLOP-balanced sharding mis-loads devices
a = sprand.banded(2000, 2000, 36, 28, seed=1)      # heavy, high-CR rows
b = sprand.banded(2000, 2000, 12, 40, seed=2)

mesh = jax.make_mesh((4,), ("data",))
plan = distributed.plan_distributed(a, b, num_shards=4)
flopr, _ = oracle.flop_per_row(a, b)

print(f"predicted NNZ(C) = {plan.predicted_nnz:,.0f}; "
      f"per-row capacity {plan.row_capacity} "
      f"(upper bound {int(flopr.max())})")
print(f"predicted-NNZ-balanced imbalance: {plan.partition.imbalance:.3f}")
p_flop = partition.balanced_contiguous(flopr, 4)
nnzr, z = oracle.exact_structure(a, b)
w = np.add.reduceat(nnzr, p_flop.bounds[:-1])
print(f"FLOP-balanced imbalance on true work: {w.max()/w.mean():.3f}")

col, val, row_nnz, ofl = distributed.distributed_spgemm(a, b, mesh, plan)
c = distributed.reassemble(plan, col, val, np.asarray(row_nnz), b.ncols)
err = np.abs(c.to_dense() - spgemm_dense_oracle(a, b)).max()
print(f"4-shard numeric phase: nnz={c.nnz:,} (exact {z:,}), "
      f"overflow={int(np.asarray(ofl).sum())}, max err={err:.2e}")
assert err < 1e-3 and c.nnz == z
print("OK — sharded SpGEMM exact, balanced, within predicted buffers.")
