"""SpGEMM-as-a-service demo (DESIGN.md §10): a batch of mixed-family
multiply requests moves through the fault-contained scheduler — admission
pricing from the paper's sampled predictor, template batching with
zero-retrace steady state, load shedding, deadline expiry, and typed
errors for everything that cannot complete.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""
import json

import numpy as np

from repro.serve import ServiceConfig, SpgemmService
from repro.sparse import random as sprand
from repro.sparse.formats import spgemm_dense_oracle

svc = SpgemmService(ServiceConfig(queue_capacity=16, max_batch=4,
                                  default_deadline=60.0))

pairs = [
    ("er", sprand.erdos_renyi(400, 400, 4, seed=1),
     sprand.erdos_renyi(400, 400, 3, seed=2)),
    ("pl", sprand.power_law(400, 400, 5, 1.5, seed=3),
     sprand.power_law(400, 400, 4, 1.6, seed=4)),
    ("band", sprand.banded(400, 400, 10, 14, seed=5),
     sprand.banded(400, 400, 8, 12, seed=6)),
]

# two rounds of each family: round 2 rides round 1's cached executors
reqs = [(fam, a, b, svc.submit(a, b))
        for _ in range(2) for fam, a, b in pairs]
svc.drain()

for fam, a, b, r in reqs:
    c = r.result_or_raise()
    np.testing.assert_allclose(c.to_dense(), spgemm_dense_oracle(a, b),
                               rtol=1e-4, atol=1e-4)
    est = r.stats["estimate"]
    print(f"req {r.id} [{fam:4s}] {r.state:8s} nnz={c.nnz:6d} "
          f"priced {est['total_bytes'] / 1e6:6.2f} MB "
          f"latency {r.latency * 1e3:7.1f} ms")

# overload: an 8-request burst against the 4 remaining queue slots +
# an impossible deadline — typed rejections, never hangs
late = svc.submit(pairs[1][1], pairs[1][2], deadline=-1.0)
burst = [svc.submit(pairs[0][1], pairs[0][2]) for _ in range(18)]
svc.drain()
shed = sum(r.state == "SHED" for r in burst)
done = sum(r.state == "DONE" for r in burst)
print(f"\nburst of {len(burst)}: {done} served, {shed} shed "
      f"(typed AdmissionRejectedError); late request -> {late.state}")

st = svc.stats()
print(f"\nservice: {st['submitted']} submitted, waves={st['waves']}, "
      f"retraces={st['plan_cache']['traces']} "
      f"(templates={st['templates']['size']})")
print(json.dumps(st["terminal"], indent=1))
assert st["in_flight"] == 0 and st["queue"]["depth"] == 0
print("OK — every request terminal, queue drained.")
