"""Batched serving example: prefill a batch of prompts into KV caches, then
decode tokens for all sequences in lock-step (deliverable (b)).

Run:  PYTHONPATH=src python examples/serve_batch.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models import transformer as T
from repro.models.schema import init_params
from repro.serve import engine

cfg = get_smoke_config("qwen2.5-32b")
params = init_params(T.build_schema(cfg, 1), jax.random.PRNGKey(0),
                     jnp.float32)

rng = np.random.default_rng(0)
B, P, N = 4, 8, 16
prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, P)), jnp.int32)

sess = engine.start_session(cfg, params, batch=B, max_len=P + N + 1)
toks = engine.generate(sess, prompts, num_tokens=N, temperature=0.0)
print("prompts:\n", np.asarray(prompts))
print("generated:\n", np.asarray(toks))
assert toks.shape == (B, N)

# sampled decoding from the same prompts
sess2 = engine.start_session(cfg, params, batch=B, max_len=P + N + 1)
toks2 = engine.generate(sess2, prompts, num_tokens=N, temperature=0.8, seed=1)
print("sampled:\n", np.asarray(toks2))
print(f"OK — decoded {B}×{N} tokens with a {P}-token prefill cache.")
