"""Quickstart: the paper in 40 lines.

Predict the output structure of C = A·B with the sampled compression ratio
(eq. 4), compare against the reference design (eq. 2) and the exact symbolic
phase, then run the numeric SpGEMM into buffers sized by the prediction.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.sparse import random as sprand
from repro.core import csr, oracle, predictor, spgemm

# A banded FEM-like matrix: compression ratio ≈ 8 (products collide heavily),
# exactly the regime where the upper-bound method over-allocates 8×.
A = sprand.banded(4000, 4000, 40, 30, seed=0)
Ad = csr.to_device(A)
mda = int(A.row_nnz.max())

# --- exact (the expensive symbolic phase the paper avoids) ---
nnzr, Z = oracle.exact_structure(A, A)
flopr, F = oracle.flop_per_row(A, A)
print(f"matrix: {A.nrows}x{A.ncols}, nnz={A.nnz:,}")
print(f"exact:   FLOP={F:,}  NNZ(C)={Z:,}  CR={F/Z:.2f}")

# --- the paper's method: sample 0.3% of rows, predict CR from f*/z* ---
s = predictor.static_sample_num(A.nrows)          # min(0.003·M, 300)
rows = predictor.draw_sample_rows(jax.random.PRNGKey(0), A.nrows, s)
pred = predictor.proposed_predict(Ad, Ad, rows, mda, mda)
e2 = (float(pred.nnz_total) - Z) / Z
print(f"proposed (eq.4):  Z2*={float(pred.nnz_total):,.0f}  "
      f"CR*={float(pred.compression_ratio):.2f}  error={e2*100:+.2f}%  "
      f"({s} sampled rows)")

# --- reference design (eq. 2) on the same samples, for contrast ---
ref = predictor.reference_predict(Ad, Ad, rows, mda, mda)
e1 = (float(ref.nnz_total) - Z) / Z
print(f"reference (eq.2): Z1*={float(ref.nnz_total):,.0f}  "
      f"error={e1*100:+.2f}%")

# --- allocate from the prediction and run the numeric phase ---
plan = predictor.AllocationPlan.from_prediction(
    np.asarray(pred.structure), flopr, safety=1.5)
print(f"allocation: {plan.row_capacity} slots/row "
      f"(upper-bound method would use {int(flopr.max())})")
out = spgemm.spgemm(Ad, Ad, row_capacity=plan.row_capacity,
                    max_deg_a=mda, max_deg_b=mda)
print(f"numeric phase: nnz={int(out.row_nnz.sum()):,} "
      f"(exact {Z:,}), overflow={int(out.overflow)}")
assert int(out.overflow) == 0 and int(out.row_nnz.sum()) == Z
print("OK — predicted allocation held the exact result.")
