"""End-to-end driver: train a ~100M-parameter model for a few hundred steps
on the synthetic pipeline, with checkpoints and restart (deliverable (b)).

The config is the xlstm-125m assigned architecture at full size (0.19B
params incl. untied head) — or pass --small for a CI-scale run.

Run:  PYTHONPATH=src python examples/train_100m.py --steps 300
      PYTHONPATH=src python examples/train_100m.py --small --steps 60
"""
import argparse
import sys

sys.argv = [sys.argv[0]]  # delegate to the launcher with explicit args below
from repro.launch.train import main as train_main  # noqa: E402

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
    args = ap.parse_args()
    argv = ["--arch", "xlstm-125m", "--steps", str(args.steps),
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
            "--batch", "4", "--seq", "256", "--lr", "1e-3"]
    if args.small:
        argv += ["--smoke", "--batch", "8", "--seq", "128"]
    first, last = train_main(argv)
    assert last < first, "loss must decrease"
    print("OK — loss decreased; checkpoints in", args.ckpt_dir)
