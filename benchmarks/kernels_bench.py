"""Pallas kernel microbench (interpret mode on CPU → correctness-path timing;
real-TPU timing is the deployment path).  Reports kernel vs jnp-ref us/call
so kernel-path regressions are visible in CI."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse import random as sprand
from repro.core import binning, csr, predictor
from repro.kernels import ops, ref
from .common import timeit, emit


def run():
    a = sprand.banded(2000, 2000, 12, 16, seed=1)
    b = sprand.erdos_renyi(2000, 2000, 6, seed=2)
    ad, bd = csr.to_device(a), csr.to_device(b)
    mda, mdb = int(a.row_nnz.max()), int(b.row_nnz.max())
    rows = predictor.draw_sample_rows(jax.random.PRNGKey(0), 2000, 6)

    t = timeit(lambda: jax.block_until_ready(
        ops.flop_per_row(ad, bd, max_deg_a=mda)))
    emit("kernel.flop_per_row.us", t * 1e6, "interpret")
    t = timeit(lambda: jax.block_until_ready(
        ref.flop_per_row_ref(ad.rpt, ad.col, jnp.diff(bd.rpt))))
    emit("kernel.flop_per_row_ref.us", t * 1e6, "jnp")

    t = timeit(lambda: jax.block_until_ready(
        ops.sampled_symbolic(ad, bd, rows, mda, mdb)[0]))
    emit("kernel.sampled_symbolic.us", t * 1e6, "interpret")
    t = timeit(lambda: jax.block_until_ready(
        ref.sampled_symbolic_ref(ad, bd, rows, mda, mdb)[0]))
    emit("kernel.sampled_symbolic_ref.us", t * 1e6, "jnp")

    t = timeit(lambda: jax.block_until_ready(
        ops.fused_flop_symbolic(ad, bd, rows, mda, mdb)[0]))
    emit("kernel.fused_flop_symbolic.us", t * 1e6, "interpret")
    t = timeit(lambda: jax.block_until_ready(
        ops.flop_rows(ad, bd, rows, max_deg_a=mda, block_rows=8)))
    emit("kernel.flop_rows.us", t * 1e6, "interpret")

    # binned vs global-pad numeric kernel on a skewed (power-law) operand:
    # the hub row forces the global path to a hub-sized F2 for every row.
    pa = sprand.power_law(600, 600, 4, 1.5, seed=3)
    pad = csr.to_device(pa)
    pmda = int(pa.row_nnz.max())
    plan = binning.build_plan(pa, pa)
    prows = jnp.arange(pa.nrows, dtype=jnp.int32)
    t = timeit(lambda: jax.block_until_ready(
        ops.spgemm_numeric(pad, pad, prows, max_deg_a=pmda, max_deg_b=pmda,
                           row_capacity=64, block_rows=8)[3]), iters=1)
    emit("kernel.spgemm_numeric_globalpad.us", t * 1e6, "interpret")

    def binned_numeric():
        for bucket in plan.buckets:
            jax.block_until_ready(ops.spgemm_numeric(
                pad, pad, jnp.asarray(bucket.rows),
                max_deg_a=bucket.deg_a, max_deg_b=bucket.deg_b,
                row_capacity=64, block_rows=min(bucket.block_rows, 8))[3])
    t = timeit(lambda: binned_numeric(), iters=1)
    emit("kernel.spgemm_numeric_binned.us", t * 1e6, "interpret")

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    t = timeit(lambda: jax.block_until_ready(
        ops.flash_attention(q, k, v, block_q=64, block_k=64)))
    emit("kernel.flash_attention.us", t * 1e6, "interpret")
    t = timeit(lambda: jax.block_until_ready(ref.attention_ref(q, k, v)))
    emit("kernel.flash_attention_ref.us", t * 1e6, "jnp")


if __name__ == "__main__":
    run()
