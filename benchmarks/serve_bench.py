"""SpGEMM service scheduler bench: throughput + tail latency + containment.

Drives :class:`repro.serve.spgemm_service.SpgemmService` (DESIGN.md §10)
with mixed 5-family traffic and measures the serving economics:

  * **steady-state throughput** — requests/s through the synchronous loop
    after template warmup (every repeat template must hit the plan cache:
    retrace count gated to ZERO);
  * **tail latency** — p50/p99/max per-request seconds from the request
    history timestamps, per family and mixed;
  * **containment bands** — a load storm against a short queue must shed
    (not hang), a deadline storm must expire (not execute), and a fault
    storm (all injectable classes) must leave every request terminal with
    the queue drained; terminal-state counts are gated to bands.

Standalone::

    PYTHONPATH=src python benchmarks/serve_bench.py [--quick]

Emits ``serve.*`` CSV rows and writes ``BENCH_serve.json`` at the repo
root (committed per PR).  ``--quick`` shrinks matrices + request counts
for CI.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from repro.core import faults, plan as plan_mod
from repro.serve.spgemm_service import (RequestState, ServiceConfig,
                                        SpgemmService)
from repro.sparse import random as sprand

try:
    from .common import emit, reset_records, write_bench_json
except ImportError:   # invoked as a script
    from common import emit, reset_records, write_bench_json

_LAST: dict = {}


def _gen(fam: str, m: int, seed: int):
    if fam == "er":
        return (sprand.erdos_renyi(m, m, 4, seed=seed),
                sprand.erdos_renyi(m, m, 3, seed=seed + 50))
    if fam == "pl":
        return (sprand.power_law(m, m, 5, 1.5, seed=seed),
                sprand.power_law(m, m, 4, 1.6, seed=seed + 50))
    if fam == "rmat":
        return (sprand.rmat(m, m, 5 * m, seed=seed),
                sprand.rmat(m, m, 4 * m, seed=seed + 50))
    if fam == "band":
        return (sprand.banded(m, m, 12, 16, seed=seed),
                sprand.banded(m, m, 10, 14, seed=seed + 50))
    if fam == "fem":
        return (sprand.banded(m // 2, m // 2, 48, 32, seed=seed),
                sprand.banded(m // 2, m // 2, 40, 30, seed=seed + 50))
    raise ValueError(fam)


FAMILIES = ("er", "pl", "rmat", "band", "fem")


def _traffic(m: int, reps: int):
    """Mixed request stream: ``reps`` rounds over all 5 families."""
    pairs = [(fam, *_gen(fam, m, seed=1000 + 10 * i))
             for i, fam in enumerate(FAMILIES)]
    return [(fam, a, b) for _ in range(reps) for fam, a, b in pairs]


def _latencies(reqs) -> dict:
    lat = np.asarray([r.latency for r in reqs if r.latency is not None])
    if not lat.size:
        return dict(p50_ms=0.0, p99_ms=0.0, max_ms=0.0)
    return dict(p50_ms=round(float(np.percentile(lat, 50)) * 1e3, 3),
                p99_ms=round(float(np.percentile(lat, 99)) * 1e3, 3),
                max_ms=round(float(lat.max()) * 1e3, 3))


def _steady_state(m: int, reps: int) -> dict:
    """Warm every family's template, then time ``reps`` repeat rounds —
    the zero-retrace serving contract, measured end to end."""
    svc = SpgemmService(ServiceConfig(queue_capacity=16 * reps,
                                      max_batch=8))
    warm = _traffic(m, 1)
    for _, a, b in warm:
        svc.submit(a, b)
    svc.drain()
    # templates may have grown during warmup: one more round settles keys
    for _, a, b in warm:
        svc.submit(a, b)
    svc.drain()
    traces0 = svc.stats()["plan_cache"]["traces"]

    stream = _traffic(m, reps)
    t0 = time.perf_counter()
    reqs = [svc.submit(a, b) for _, a, b in stream]
    svc.drain()
    wall = time.perf_counter() - t0
    st = svc.stats()
    return dict(
        requests=len(reqs),
        wall_s=round(wall, 4),
        throughput_rps=round(len(reqs) / wall, 2),
        retraces=st["plan_cache"]["traces"] - traces0,
        waves=st["waves"],
        batched_per_wave=round(st["batched_requests"] / max(st["waves"], 1),
                               2),
        done=sum(r.state == RequestState.DONE for r in reqs),
        **_latencies(reqs),
    )


def _overload(m: int, reps: int) -> dict:
    """Storm a short queue: the overflow must shed typed, the admitted
    remainder must all complete, and nothing may hang."""
    svc = SpgemmService(ServiceConfig(queue_capacity=8, max_batch=8))
    reqs = [svc.submit(a, b) for _, a, b in _traffic(m, reps)]
    svc.drain()
    st = svc.stats()
    term = st["terminal"]
    return dict(requests=len(reqs), shed=term["SHED"], done=term["DONE"],
                queue_depth=st["queue"]["depth"],
                in_flight=st["in_flight"])


def _deadline_storm(m: int) -> dict:
    """Every queued-behind request carries an already-hopeless deadline:
    the service must expire them at the next scheduling point instead of
    executing stale work."""
    t = [0.0]
    svc = SpgemmService(ServiceConfig(), clock=lambda: t[0])
    fam, a, b = "er", *_gen("er", m, seed=77)
    live = svc.submit(a, b)
    doomed = [svc.submit(a, b, deadline=0.5) for _ in range(10)]
    t[0] = 1.0
    svc.drain()
    return dict(expired=sum(r.state == RequestState.EXPIRED for r in doomed),
                doomed=len(doomed),
                live_done=live.state == RequestState.DONE)


def _fault_storm(m: int, reps: int) -> dict:
    """Chaos rounds (capacity / sketch / executor faults) — every request
    terminal, queue drained, failures typed."""
    svc = SpgemmService(ServiceConfig(queue_capacity=16 * reps,
                                      breaker_cooldown=0.0))
    storms = [dict(capacity_scale=0.2), dict(sketch_scale=0.05),
              dict(fail_executor={"unit": "local"})]
    reqs = []
    for i, storm in enumerate(storms * max(1, reps // 3)):
        reqs.extend(svc.submit(a, b) for _, a, b in _traffic(m, 1))
        with faults.inject(seed=i, **storm):
            svc.drain()
    st = svc.stats()
    return dict(requests=len(reqs),
                terminal=dict(st["terminal"]),
                all_terminal=all(r.done for r in reqs),
                typed_errors=all(r.error is None
                                 or isinstance(r.error, ValueError)
                                 for r in reqs),
                queue_depth=st["queue"]["depth"],
                requeues=st["requeues"])


def run(quick: bool = False):
    _LAST.clear()
    m = 400 if quick else 1500
    reps = 4 if quick else 10
    _LAST["steady"] = _steady_state(m, reps)
    _LAST["overload"] = _overload(m, reps)
    _LAST["deadline"] = _deadline_storm(m)
    _LAST["faults"] = _fault_storm(m, reps)
    s = _LAST["steady"]
    emit("serve.steady.throughput.rps", s["throughput_rps"],
         "mixed 5-family repeat traffic, warmed templates")
    emit("serve.steady.p99.ms", s["p99_ms"], "per-request latency")
    emit("serve.steady.retraces.n", s["retraces"],
         "steady-state repeat traffic (gated to 0)")
    emit("serve.steady.batch.x", s["batched_per_wave"],
         "requests per dispatch wave")
    emit("serve.overload.shed.n", _LAST["overload"]["shed"],
         "typed sheds under queue storm")
    emit("serve.deadline.expired.n", _LAST["deadline"]["expired"],
         "hopeless deadlines expired, not executed")
    emit("serve.faults.requeues.n", _LAST["faults"]["requeues"],
         "escalated capacity requeues under chaos")


def summary() -> dict:
    return dict(_LAST)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true",
                   help="CI-sized matrices + request counts")
    args = p.parse_args(argv)
    reset_records()
    run(quick=args.quick)
    out = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "BENCH_serve.json"))
    write_bench_json(out, extra=dict(serve=summary(), quick=args.quick))
    print(json.dumps(summary(), indent=1))
    print(f"wrote {out}")

    ok = True
    s = summary()
    if s["steady"]["retraces"] != 0:
        print(f"FAIL: steady-state traffic retraced "
              f"{s['steady']['retraces']} executors")
        ok = False
    if s["steady"]["done"] != s["steady"]["requests"]:
        print("FAIL: steady-state traffic must complete clean")
        ok = False
    ov = s["overload"]
    if ov["shed"] + ov["done"] != ov["requests"] or ov["queue_depth"] \
            or ov["in_flight"]:
        print(f"FAIL: overload storm leaked requests: {ov}")
        ok = False
    if ov["shed"] == 0:
        print("FAIL: overload storm must shed against an 8-slot queue")
        ok = False
    dl = s["deadline"]
    if dl["expired"] != dl["doomed"] or not dl["live_done"]:
        print(f"FAIL: deadline storm mis-triaged: {dl}")
        ok = False
    fl = s["faults"]
    if not (fl["all_terminal"] and fl["typed_errors"]
            and fl["queue_depth"] == 0):
        print(f"FAIL: fault storm containment: {fl}")
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
