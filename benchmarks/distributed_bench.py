"""Distributed SpGEMM: binned-routed plan/execute vs the legacy global-pad
shard path, per suite family, on a 4-device host mesh.

The acceptance metric for the unified pipeline (DESIGN.md §6): the power-law
family's distributed numeric phase must beat the legacy global-pad shard
path (the binned buffers are what the PR 1/2 lane reductions buy at pod
scale), uniform families must not regress materially, and the plan cache
must serve a second same-signature pair with ZERO executor retraces
(the serving scenario) — measured and checked here.

Standalone (sets the device-count env before jax init):

    PYTHONPATH=src python benchmarks/distributed_bench.py [--quick]

Emits ``dist.*`` CSV rows and writes ``BENCH_distributed.json`` at the repo
root (the perf-trajectory artifact committed per PR).  ``--quick`` shrinks
the matrices for CI.
"""
from __future__ import annotations

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import argparse
import json
import sys

import jax
import numpy as np

from repro.sparse import random as sprand
from repro.sparse.formats import CSR
from repro.core import oracle
from repro.core import plan as plan_mod

try:
    from .common import timeit, emit, reset_records, write_bench_json
    from . import legacy_distributed as distributed
except ImportError:   # invoked as a script: python benchmarks/distributed_bench.py
    from common import timeit, emit, reset_records, write_bench_json
    import legacy_distributed as distributed

_LAST: dict = {}


def _cases(quick: bool):
    s = 4 if quick else 1
    return [
        ("er", sprand.erdos_renyi(2000 // s, 2000 // s, 4, seed=61),
         sprand.erdos_renyi(2000 // s, 2000 // s, 3, seed=62)),
        ("pl", sprand.power_law(2000 // s, 2000 // s, 5, 1.5, seed=11),
         sprand.power_law(2000 // s, 2000 // s, 4, 1.6, seed=12)),
        ("band", sprand.banded(2000 // s, 2000 // s, 12, 16, seed=13),
         sprand.banded(2000 // s, 2000 // s, 10, 14, seed=14)),
        ("fem", sprand.banded(1200 // s, 1200 // s, 48, 32, seed=51),
         sprand.banded(1200 // s, 1200 // s, 40, 30, seed=52)),
    ]


def _revalue(m: CSR, seed: int) -> CSR:
    rng = np.random.default_rng(seed)
    return CSR(rpt=m.rpt.copy(), col=m.col.copy(),
               val=rng.standard_normal(m.nnz).astype(np.float32),
               shape=m.shape)


def run(quick: bool = False):
    _LAST.clear()
    shards = min(4, len(jax.devices()))
    mesh = jax.make_mesh((shards,), ("data",))
    for fam, a, b in _cases(quick):
        # -- legacy global-pad shard path -------------------------------- #
        lplan = distributed.plan_distributed(a, b, num_shards=shards)
        t_legacy = timeit(lambda: jax.block_until_ready(
            distributed.distributed_spgemm(a, b, mesh, lplan)[3]))
        legacy_slots = int(lplan.row_table.shape[1] * lplan.row_capacity)

        # -- unified binned-routed plan/execute -------------------------- #
        cache = plan_mod.PlanCache()
        t_plan = timeit(lambda: plan_mod.plan_spgemm(a, b, mesh=mesh),
                        warmup=1, iters=3)
        plan = plan_mod.plan_spgemm(a, b, mesh=mesh)
        t_binned = timeit(lambda: plan_mod.execute(plan, a, b, cache=cache))

        # correctness cross-check against the exact symbolic structure
        res = plan_mod.execute(plan, a, b, cache=cache)
        c = plan_mod.reassemble(plan, res)
        _, z = oracle.exact_structure(a, b)
        assert c.nnz == z, (fam, c.nnz, z)

        # -- serving: same structure, new values, cache-served ----------- #
        a2, b2 = _revalue(a, 91), _revalue(b, 92)
        traces_before = cache.stats()["traces"]
        plan2 = plan_mod.plan_spgemm(a2, b2, mesh=mesh)
        same_key = plan2.key == plan.key
        t_cached = timeit(lambda: plan_mod.execute(plan2, a2, b2, cache=cache))
        retraces = cache.stats()["traces"] - traces_before

        speedup = t_legacy / max(t_binned, 1e-12)
        emit(f"dist.{fam}.legacy_numeric.us", t_legacy * 1e6, "global-pad")
        emit(f"dist.{fam}.binned_numeric.us", t_binned * 1e6, "binned-routed")
        emit(f"dist.{fam}.numeric_speedup.x", speedup, "legacy/binned")
        emit(f"dist.{fam}.plan.us", t_plan * 1e6, "plan_spgemm")
        emit(f"dist.{fam}.cache_numeric.us", t_cached * 1e6, "cache-served")
        emit(f"dist.{fam}.retraces.n", retraces, "serving pair")
        _LAST[fam] = dict(
            shards=shards,
            legacy_us=round(t_legacy * 1e6, 1),
            binned_us=round(t_binned * 1e6, 1),
            cached_us=round(t_cached * 1e6, 1),
            speedup=round(speedup, 3),
            plan_us=round(t_plan * 1e6, 1),
            retraces=int(retraces),
            cache=cache.stats(),
            same_key=bool(same_key),
            imbalance=round(float(plan.partition.imbalance), 4),
            shard_slots=plan.shard_slots(),
            legacy_shard_slots=legacy_slots,
            slots_reduction=round(legacy_slots / max(1, plan.shard_slots()), 3),
            overflow=int(res.shard_overflow.sum()),
            lane_reduction=round(plan.binning.lane_reduction, 3),
        )


def summary() -> dict:
    """Machine-readable results of the last run() (for the JSON artifact)."""
    return dict(_LAST)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true",
                   help="CI-sized matrices (rows/4)")
    args = p.parse_args(argv)
    reset_records()
    run(quick=args.quick)
    out = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "BENCH_distributed.json"))
    write_bench_json(out, extra=dict(distributed=summary(), quick=args.quick))
    print(json.dumps(summary(), indent=1))
    print(f"wrote {out}")
    ok = True
    for fam, s in summary().items():
        if s["retraces"] != 0 or not s["same_key"]:
            print(f"FAIL: {fam} serving pair retraced "
                  f"({s['retraces']} traces, same_key={s['same_key']})")
            ok = False
        if s["overflow"]:
            print(f"FAIL: {fam} dropped {s['overflow']} entries")
            ok = False
    if args.quick:
        return 0 if ok else 1   # CI smoke: timings are dispatch-dominated
    # full-scale acceptance gates (ISSUE 3)
    if summary()["pl"]["speedup"] < 1.0:
        print("FAIL: power-law distributed numeric phase slower than the "
              f"legacy global-pad path ({summary()['pl']['speedup']}x)")
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
