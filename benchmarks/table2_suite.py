"""Paper Table II: the 25-matrix suite with CR(A²) spread.

Reads the cached 625-case artifact (the A² diagonal cases) when present;
otherwise computes a fast mini-suite live.
"""
from __future__ import annotations

from .common import load_artifact, emit


def run():
    art = load_artifact("accuracy_625.json")
    if art is not None:
        names = sorted({c["A"] for c in art["cases"]})
        diag = {c["A"]: c for c in art["cases"] if c["A"] == c["B"]}
        print("# Table II analogue: suite matrix stats (A^2 cases)")
        print("name,flop_A2,nnz_A2,cr_A2")
        for n in names:
            c = diag[n]
            print(f"{n},{c['flop']},{c['nnz']},{c['cr']:.2f}")
        crs = [diag[n]["cr"] for n in names]
        emit("table2.cr_min", 0.0, f"{min(crs):.2f}")
        emit("table2.cr_max", 0.0, f"{max(crs):.2f}")
        emit("table2.n_matrices", 0.0, str(len(names)))
        return
    # live mini fallback
    from repro.sparse.suite import mini_suite
    from repro.core import oracle
    print("# Table II analogue (mini, live)")
    print("name,flop_A2,nnz_A2,cr_A2")
    for name, m in mini_suite():
        _, f = oracle.flop_per_row(m, m)
        _, z = oracle.exact_structure(m, m)
        print(f"{name},{f},{z},{f/z:.2f}")


if __name__ == "__main__":
    run()
