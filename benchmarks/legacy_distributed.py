"""Distributed SpGEMM — the LEGACY global-pad shard path (BENCHMARK BASELINE).

Retired from the library (it lived at ``repro.core.distributed`` through
PR 4): one global ``row_capacity`` (sized by the worst predicted row in the
whole matrix) and one global-degree sort-merge pass per shard, with A AND B
fully replicated to every device.  It survives only here, as the baseline
``benchmarks/distributed_bench.py`` / ``benchmarks/comm_bench.py`` measure
the unified pipeline against; library code uses the planner/executor in
:mod:`repro.core.plan` (DESIGN.md §6–§8), which runs each shard through the
binned routed kernels with per-bucket-per-shard capacities — and, with
``n_panels``, column-partitions B instead of replicating it::

    plan = plan_spgemm(a, b, mesh=mesh,
                       pop_quant=True,      # pow2-quantized plan-cache keys
                       retry_safety=1.5)    # overflow re-planning loop
    out  = execute(plan, a, b)        # DistSpgemmOut, per-shard overflow
    c    = reassemble(plan, out)

(This legacy path only *surfaces* overflow through ``reassemble``; the
unified pipeline's armed retry loop re-executes the overflowing buckets
instead — DESIGN.md §7.)

The original paper pipeline at pod scale (DESIGN §3/§4):

  1. predict the output structure (sampled CR, eq. 4) on host,
  2. partition output rows into `data`-axis shards with ~equal PREDICTED
     output nnz (not FLOP — FLOP-balancing mis-sizes shards by exactly the
     compression ratio the paper predicts),
  3. size the per-shard static output buffers from the prediction,
  4. shard_map the numeric phase: each device computes its row range with
     the sort-merge accumulator; no cross-device traffic in the numeric
     phase (A/B index arrays are broadcast once).

Returns per-shard padded CSR blocks + the partition (for reassembly).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.sparse.formats import CSR
from repro.core import csr as csr_mod
from repro.core import oracle
from repro.core import partition as part_mod
from repro.core.spgemm import gather_products, _accumulate_block


@dataclasses.dataclass
class DistSpGEMMPlan:
    row_table: np.ndarray      # (shards, rows_per_shard) int32
    row_valid: np.ndarray      # (shards, rows_per_shard) bool
    row_capacity: int
    partition: part_mod.Partition
    predicted_nnz: float


def plan_distributed(a: CSR, b: CSR, num_shards: int, *, seed: int = 0,
                     safety: float = 1.3) -> DistSpGEMMPlan:
    flopr, _ = oracle.flop_per_row(a, b)
    pred = oracle.proposed_predict(a, b, seed=seed)
    part = part_mod.balanced_contiguous(pred.structure, num_shards)
    rows_per_shard = int(max(np.diff(part.bounds).max(), 1))
    table = part_mod.static_row_assignment(part, rows_per_shard)
    valid = np.zeros_like(table, dtype=bool)
    for i in range(num_shards):
        n = int(part.bounds[i + 1] - part.bounds[i])
        valid[i, :min(n, rows_per_shard)] = True
    plan_cap = int(min(np.ceil(pred.structure.max() * safety),
                       flopr.max()))
    plan_cap = max(8, -(-plan_cap // 8) * 8)
    return DistSpGEMMPlan(table, valid, plan_cap, part, float(pred.nnz_total))


def distributed_spgemm(a: CSR, b: CSR, mesh, plan: DistSpGEMMPlan, *,
                       axis: str = "data", max_deg_a: int | None = None,
                       max_deg_b: int | None = None):
    """Run the numeric phase across ``mesh[axis]`` shards.

    Returns (col (S, R, cap), val (S, R, cap), row_nnz (S, R), overflow (S,)).
    """
    mda = max_deg_a or max(1, int(a.row_nnz.max(initial=0)))
    mdb = max_deg_b or max(1, int(b.row_nnz.max(initial=0)))
    ad = csr_mod.to_device(a)
    bd = csr_mod.to_device(b)
    rows = jnp.asarray(plan.row_table)
    cap = plan.row_capacity

    def shard_fn(rows_blk):
        # rows_blk: (1, rows_per_shard) — this shard's rows
        cols, vals, _ = gather_products(ad, bd, rows_blk[0], mda, mdb)
        oc, ov, nnz, ofl = _accumulate_block(cols, vals, cap)
        return (oc[None], ov[None], nnz[None], ofl[None])

    spec_in = P(axis, None)
    fn = shard_map(shard_fn, mesh=mesh, in_specs=(spec_in,),
                   out_specs=(P(axis, None, None), P(axis, None, None),
                              P(axis, None), P(axis)),
                   check_rep=False)
    oc, ov, nnz, ofl = jax.jit(fn)(rows)
    return oc, ov, nnz, ofl


def reassemble(plan: DistSpGEMMPlan, col, val, row_nnz, ncols: int, *,
               overflow=None, on_overflow: str = "raise") -> CSR:
    """Host-side: stitch shard outputs back into one CSR (tests/examples).

    Pass the per-shard ``overflow`` array from :func:`distributed_spgemm`
    to surface dropped entries: nonzero overflow RAISES by default instead
    of silently returning a truncated matrix (``on_overflow="ignore"``
    opts back into truncation).  Omitting ``overflow`` keeps the legacy
    no-check behavior.
    """
    if overflow is not None:
        from repro.core.plan import _check_overflow
        _check_overflow(int(np.asarray(overflow).sum()), overflow,
                        on_overflow)
    # seed with typed empties: all-empty shard outputs (every row zero nnz,
    # or no valid rows at all) must reassemble to an empty CSR, not crash
    # np.concatenate on an empty list
    rows_out = [np.zeros(0, np.int64)]
    cols_out = [np.zeros(0, np.int64)]
    vals_out = [np.zeros(0, np.float32)]
    col = np.asarray(col)
    val = np.asarray(val)
    for s in range(plan.row_table.shape[0]):
        for r in range(plan.row_table.shape[1]):
            if not plan.row_valid[s, r]:
                continue
            rid = int(plan.row_table[s, r])
            c = col[s, r]
            m = c != csr_mod.COL_SENTINEL
            rows_out.append(np.full(int(m.sum()), rid, dtype=np.int64))
            cols_out.append(c[m].astype(np.int64))
            vals_out.append(val[s, r][m])
    nrows = int(plan.partition.bounds[-1])
    return CSR.from_coo(np.concatenate(rows_out), np.concatenate(cols_out),
                        np.concatenate(vals_out).astype(np.float32),
                        (nrows, ncols), dedup=False)
