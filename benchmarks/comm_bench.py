"""Communication / memory-footprint bench: column-partitioned B vs the
replicated-B executor (DESIGN.md §8), per suite family, on a 4-device host
mesh.

The acceptance metric for the panel-gathered numeric phase (ISSUE 5): on
the power-law family, the per-device B index+value footprint must drop by
≥ ~``n_panels``× vs the replicated executor (measured as the true gathered
payload — pow2 capacity padding is reported separately), with ZERO
retraces on a steady-state repeated multiply (same structure, new values —
compile-count-pinned like ``distributed_bench``).

Standalone (sets the device-count env before jax init):

    PYTHONPATH=src python benchmarks/comm_bench.py [--quick]

Emits ``comm.*`` CSV rows and writes ``BENCH_comm.json`` at the repo root
(the perf-trajectory artifact committed per PR).  ``--quick`` shrinks the
matrices for CI.
"""
from __future__ import annotations

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import argparse
import json
import sys

import jax
import numpy as np

from repro.sparse import random as sprand
from repro.sparse.formats import CSR
from repro.core import oracle
from repro.core import plan as plan_mod

try:
    from .common import timeit, emit, reset_records, write_bench_json
except ImportError:   # invoked as a script: python benchmarks/comm_bench.py
    from common import timeit, emit, reset_records, write_bench_json

_LAST: dict = {}


def _cases(quick: bool):
    s = 4 if quick else 1
    return [
        ("er", sprand.erdos_renyi(2000 // s, 2000 // s, 8, seed=61),
         sprand.erdos_renyi(2000 // s, 2000 // s, 6, seed=62)),
        ("pl", sprand.power_law(2000 // s, 2000 // s, 8, 1.5, seed=11),
         sprand.power_law(2000 // s, 2000 // s, 6, 1.6, seed=12)),
        ("band", sprand.banded(2000 // s, 2000 // s, 12, 16, seed=13),
         sprand.banded(2000 // s, 2000 // s, 10, 14, seed=14)),
    ]


def _revalue(m: CSR, seed: int) -> CSR:
    rng = np.random.default_rng(seed)
    return CSR(rpt=m.rpt.copy(), col=m.col.copy(),
               val=rng.standard_normal(m.nnz).astype(np.float32),
               shape=m.shape)


def run(quick: bool = False):
    _LAST.clear()
    shards = min(4, len(jax.devices()))
    if shards < 2:
        raise SystemExit(
            "comm_bench needs a multi-device host mesh; line 23 only "
            "DEFAULTS XLA_FLAGS — unset it or include "
            "--xla_force_host_platform_device_count=4 in it")
    mesh = jax.make_mesh((shards,), ("data",))
    for fam, a, b in _cases(quick):
        rec = {}
        # replicated-B reference executor (timing); its per-device footprint
        # comes from the panel plans' own comm_stats accounting so the bench
        # can never diverge from the plan's acceptance metric
        rep = plan_mod.plan_spgemm(a, b, mesh=mesh)
        rep_bytes = None
        cache = plan_mod.PlanCache()
        t_rep = timeit(lambda: plan_mod.execute(rep, a, b, cache=cache))
        for n_panels in dict.fromkeys((2, shards)):  # dedup at shards == 2
            pcache = plan_mod.PlanCache()
            plan = plan_mod.plan_spgemm(a, b, mesh=mesh, n_panels=n_panels)
            t_pan = timeit(lambda: plan_mod.execute(plan, a, b,
                                                    cache=pcache))
            res = plan_mod.execute(plan, a, b, cache=pcache)
            c = plan_mod.reassemble(plan, res)
            _, z = oracle.exact_structure(a, b)
            assert c.nnz == z, (fam, n_panels, c.nnz, z)

            # steady state: same structure, new values, cache-served
            a2, b2 = _revalue(a, 91), _revalue(b, 92)
            traces_before = pcache.stats()["traces"]
            plan2 = plan_mod.plan_spgemm(a2, b2, mesh=mesh,
                                         n_panels=n_panels)
            same_key = plan2.key == plan.key
            t_cached = timeit(lambda: plan_mod.execute(plan2, a2, b2,
                                                       cache=pcache))
            retraces = pcache.stats()["traces"] - traces_before

            comm = plan.comm_stats()
            rep_bytes = comm["replicated_b_bytes"]   # same cap_b every plan
            tag = f"comm.{fam}.p{n_panels}"
            emit(f"{tag}.per_device_b.bytes", comm["per_device_b_bytes"],
                 "panel-gathered")
            emit(f"{tag}.footprint_reduction.x",
                 comm["footprint_reduction"], "replicated/panel padded")
            emit(f"{tag}.payload_reduction.x", comm["payload_reduction"],
                 "B nnz / max gathered")
            emit(f"{tag}.gathered.bytes", comm["gathered_bytes_total"],
                 "all-to-all volume")
            emit(f"{tag}.numeric.us", t_pan * 1e6, "panel-gathered")
            emit(f"{tag}.cache_numeric.us", t_cached * 1e6, "cache-served")
            emit(f"{tag}.retraces.n", retraces, "serving pair")
            rec[f"p{n_panels}"] = dict(
                comm=comm,
                numeric_us=round(t_pan * 1e6, 1),
                cached_us=round(t_cached * 1e6, 1),
                retraces=int(retraces),
                same_key=bool(same_key),
                overflow=int(res.shard_overflow.sum()),
            )
        emit(f"comm.{fam}.replicated_b.bytes", rep_bytes, "legacy layout")
        emit(f"comm.{fam}.replicated_numeric.us", t_rep * 1e6,
             "replicated-B")
        rec["replicated"] = dict(b_bytes=int(rep_bytes),
                                 numeric_us=round(t_rep * 1e6, 1))
        _LAST[fam] = rec


def summary() -> dict:
    """Machine-readable results of the last run() (for the JSON artifact)."""
    return dict(_LAST)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true",
                   help="CI-sized matrices (rows/4)")
    args = p.parse_args(argv)
    reset_records()
    run(quick=args.quick)
    out = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "BENCH_comm.json"))
    write_bench_json(out, extra=dict(comm=summary(), quick=args.quick))
    print(json.dumps(summary(), indent=1))
    print(f"wrote {out}")
    ok = True
    npan = min(4, len(jax.devices()))
    for fam, rec in summary().items():
        for k, s in rec.items():
            if k == "replicated":
                continue
            if s["retraces"] != 0 or not s["same_key"]:
                print(f"FAIL: {fam}.{k} steady-state pair retraced "
                      f"({s['retraces']} traces, same_key={s['same_key']})")
                ok = False
            if s["overflow"]:
                print(f"FAIL: {fam}.{k} dropped {s['overflow']} entries")
                ok = False
            if s["comm"]["per_device_b_bytes"] \
                    >= rec["replicated"]["b_bytes"]:
                print(f"FAIL: {fam}.{k} panel footprint not below the "
                      "replicated operand")
                ok = False
    if args.quick:
        return 0 if ok else 1   # CI smoke: timings are dispatch-dominated
    # full-scale acceptance gate (ISSUE 5): ~n_panels× B footprint drop on pl
    pl = summary()["pl"][f"p{npan}"]["comm"]
    if pl["payload_reduction"] < 0.75 * npan:
        print(f"FAIL: power-law per-device B payload reduced only "
              f"{pl['payload_reduction']}x (need ≥ ~{npan}x)")
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
