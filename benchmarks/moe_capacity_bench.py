"""Beyond-paper benchmark: sampled-CR expert-capacity prediction vs the
worst-case capacity-factor allocation (DESIGN §4).

Measures (a) prediction accuracy of the block count, and (b) buffer savings
vs the upper-bound allocation at equal drop-safety, across routing skews."""
from __future__ import annotations

import numpy as np

from repro.core import moe_capacity
from .common import emit


def run():
    rng = np.random.default_rng(0)
    tokens, k, e, gsz = 500_000, 8, 256, 1024
    print("# MoE dispatch-block prediction (tokens=500k, E=256, top-8)")
    print("skew,exact_blocks,predicted_blocks,rel_err_pct,"
          "upper_bound_blocks,buffer_saving_pct")
    for skew in [0.0, 0.5, 1.0, 1.5]:
        p = np.arange(1, e + 1, dtype=np.float64) ** (-skew)
        p /= p.sum()
        ids = rng.choice(e, size=(tokens, k), p=p)
        plan = moe_capacity.predict_dispatch_capacity(ids, e, gsz, seed=1)
        exact = moe_capacity.exact_dispatch_blocks(ids, gsz)
        rel = abs(plan.predicted_blocks - exact) / exact * 100
        upper = tokens * k  # upper bound: every assignment its own block
        saving = (1 - plan.block_buffer_size() / upper) * 100
        print(f"{skew},{exact},{plan.predicted_blocks:.0f},{rel:.2f},"
              f"{upper},{saving:.1f}")
        emit(f"moe_capacity.rel_err_pct.skew{skew}", 0.0, f"{rel:.2f}")
    emit("moe_capacity.group_size", 0.0, str(gsz))


if __name__ == "__main__":
    run()
