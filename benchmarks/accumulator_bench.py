"""SPA vs ESC accumulator backends on the suite families (DESIGN.md §5).

The acceptance metric for the hybrid accumulator backend: on the dense-ish
regimes that degree binning left at ~1× (banded / FEM / mid-degree ER — all
compact column spaces with wide gather buffers) the planner must select the
SPA route and the symbolic phase must run ≥2× faster than the sort route,
while power-law families (wide column spaces) stay routed to ESC and are
unregressed (their auto plan IS the esc plan).  Symbolic ``z*``/``f*`` must
be bitwise-equal across routes and numeric outputs allclose with identical
overflow accounting — measured and checked here on every family.

Emits ``accum.*`` CSV rows and writes ``BENCH_accumulators.json`` at the
repo root (the perf-trajectory artifact committed per PR).  ``--quick``
shrinks the matrices for CI.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse import random as sprand
from repro.core import binning, csr, predictor, spgemm
from repro.core.flop import flop_per_row

try:
    from .common import timeit, emit, reset_records, write_bench_json
except ImportError:      # invoked as a script: python benchmarks/accumulator_bench.py
    from common import timeit, emit, reset_records, write_bench_json

_LAST: dict = {}


def _cases(quick: bool):
    s = 4 if quick else 1
    return [
        # band/fem mirror the suite's band_40k_d24 / fem_24k_d56 regimes
        ("band", sprand.banded(2000 // s, 2000 // s, 24, 30, seed=13),
         sprand.banded(2000 // s, 2000 // s, 20, 26, seed=14)),
        ("fem", sprand.banded(1200 // s, 1200 // s, 48, 32, seed=51),
         sprand.banded(1200 // s, 1200 // s, 40, 30, seed=52)),
        ("er", sprand.erdos_renyi(2000 // s, 2000 // s, 10, seed=15),
         sprand.erdos_renyi(2000 // s, 2000 // s, 8, seed=16)),
        ("pl", sprand.power_law(3000 // s, 3000 // s, 5, 1.5, seed=11),
         sprand.power_law(3000 // s, 3000 // s, 4, 1.6, seed=12)),
    ]


def run(quick: bool = False):
    _LAST.clear()
    for fam, a, b in _cases(quick):
        ad, bd = csr.to_device(a), csr.to_device(b)
        plans = {r: binning.build_plan(a, b, route=r)
                 for r in ("auto", "esc", "spa")}
        routes = plans["auto"].route_rows()
        # the paper's 0.003·M sampling gives single-digit rows at bench
        # scale — far below timer resolution; an inflated sample keeps the
        # per-sample phase cost measurable (counts stay route-invariant)
        rows = predictor.draw_sample_rows(
            jax.random.PRNGKey(0), a.nrows, min(512, a.nrows))

        # -- symbolic phase (the z*/f* counting pass), per route ---------- #
        sym_us, counts = {}, {}
        for mode, use_kernel in (("jnp", False), ("kernel", True)):
            for r in ("esc", "spa", "auto"):
                fn = lambda r=r, uk=use_kernel: jax.block_until_ready(
                    predictor.binned_symbolic_counts(
                        ad, bd, rows, plans[r], use_kernel=uk)[0])
                # symbolic runs are sub-ms: extra iters keep the ratio stable
                sym_us[(mode, r)] = timeit(fn, warmup=2, iters=7) * 1e6
                z, f = predictor.binned_symbolic_counts(
                    ad, bd, rows, plans[r], use_kernel=use_kernel)
                counts[(mode, r)] = (int(z), int(f))
        zf = set(counts.values())
        assert len(zf) == 1, f"z*/f* not route-invariant on {fam}: {counts}"

        # -- numeric phase, per route ------------------------------------- #
        floprc, _ = flop_per_row(ad, bd)
        pred = predictor.proposed_predict_binned(ad, bd, rows, plans["esc"])
        num_us, outs = {}, {}
        for r in ("esc", "spa", "auto"):
            balloc = predictor.BinnedAllocationPlan.from_prediction(
                plans[r], np.asarray(pred.structure), np.asarray(floprc),
                safety=1.5)
            num_us[r] = timeit(lambda r=r, al=balloc: jax.block_until_ready(
                spgemm.spgemm_binned(ad, bd, plans[r], alloc=al).overflow)) * 1e6
            outs[r] = spgemm.spgemm_binned(ad, bd, plans[r], alloc=balloc)
        for r in ("spa", "auto"):
            np.testing.assert_array_equal(np.asarray(outs["esc"].col),
                                          np.asarray(outs[r].col))
            np.testing.assert_allclose(np.asarray(outs["esc"].val),
                                       np.asarray(outs[r].val),
                                       rtol=1e-4, atol=1e-4)
            np.testing.assert_array_equal(np.asarray(outs["esc"].row_nnz),
                                          np.asarray(outs[r].row_nnz))
            assert int(outs["esc"].overflow) == int(outs[r].overflow)

        sym_speedup_jnp = sym_us[("jnp", "esc")] / max(sym_us[("jnp", "auto")], 1e-9)
        sym_speedup_kernel = (sym_us[("kernel", "esc")] /
                              max(sym_us[("kernel", "auto")], 1e-9))
        num_speedup = num_us["esc"] / max(num_us["auto"], 1e-9)
        for (mode, r), us in sym_us.items():
            emit(f"accum.{fam}.symbolic_{mode}_{r}.us", us, r)
        for r, us in num_us.items():
            emit(f"accum.{fam}.numeric_{r}.us", us, r)
        emit(f"accum.{fam}.symbolic_speedup_kernel.x", sym_speedup_kernel,
             "esc/auto")
        emit(f"accum.{fam}.symbolic_speedup_jnp.x", sym_speedup_jnp,
             "esc/auto")
        emit(f"accum.{fam}.numeric_speedup.x", num_speedup, "esc/auto")
        _LAST[fam] = dict(
            routes=routes,
            spa_fraction=round(routes["spa"] / max(1, sum(routes.values())), 3),
            z_star=zf.pop()[0],
            symbolic_us={f"{m}_{r}": round(v, 1)
                         for (m, r), v in sym_us.items()},
            numeric_us={r: round(v, 1) for r, v in num_us.items()},
            symbolic_speedup_kernel=round(sym_speedup_kernel, 3),
            symbolic_speedup_jnp=round(sym_speedup_jnp, 3),
            numeric_speedup=round(num_speedup, 3),
            overflow=int(outs["esc"].overflow),
        )


def summary() -> dict:
    """Machine-readable results of the last run() (for the JSON artifacts)."""
    return dict(_LAST)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true",
                   help="CI-sized matrices (rows/4)")
    args = p.parse_args(argv)
    reset_records()
    run(quick=args.quick)
    out = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "BENCH_accumulators.json"))
    write_bench_json(out, extra=dict(accumulators=summary(),
                                     quick=args.quick))
    print(json.dumps(summary(), indent=1))
    print(f"wrote {out}")
    if args.quick:
        return 0      # CI smoke: equivalence checked, timings are
                      # dispatch-overhead-dominated at quick scale
    # sanity gates mirroring the PR acceptance criteria (full scale only)
    ok = True
    for fam, s in summary().items():
        if fam == "pl" and s["spa_fraction"] > 0:
            print(f"FAIL: {fam} expected all-ESC routing"); ok = False
        if s["spa_fraction"] > 0.5 and s["symbolic_speedup_kernel"] < 2.0:
            print(f"FAIL: {fam} SPA-routed but kernel symbolic speedup "
                  f"{s['symbolic_speedup_kernel']}x < 2x"); ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
