"""Roofline report over the dry-run artifacts (§Roofline deliverable).

Reads artifacts/dryrun/*.json (written by repro.launch.dryrun) and emits the
per-(arch × shape × mesh) three-term table plus dominant bottlenecks."""
from __future__ import annotations

import glob
import json
import os

from .common import art_path, emit


def run():
    files = sorted(glob.glob(os.path.join(art_path("dryrun"), "*.json")))
    if not files:
        print("# no dryrun artifacts — run: "
              "PYTHONPATH=src python -m repro.launch.dryrun")
        emit("roofline.cells", 0.0, "0")
        return
    print("# roofline per cell (seconds per step; v5e constants)")
    print("arch,shape,mesh,compute_s,memory_s,collective_s,bottleneck,"
          "useful_flops_ratio,temp_bytes_per_dev")
    bnecks = {"compute": 0, "memory": 0, "collective": 0}
    for f in files:
        r = json.load(open(f))
        rl = r["roofline"]
        bnecks[rl["bottleneck"]] += 1
        print(f"{r['arch']},{r['shape']},{r['mesh']},{rl['compute_s']:.5f},"
              f"{rl['memory_s']:.5f},{rl['collective_s']:.5f},"
              f"{rl['bottleneck']},{rl['useful_flops_ratio']:.3f},"
              f"{r['memory_analysis'].get('temp_size', 0)}")
    emit("roofline.cells", 0.0, str(len(files)))
    for k, v in bnecks.items():
        emit(f"roofline.bottleneck.{k}", 0.0, str(v))


if __name__ == "__main__":
    run()
