"""Benchmark harness: one module per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV rows (plus table sections as
comment/CSV blocks) and writes ``BENCH_kernels.json`` at the repo root —
the machine-readable perf trajectory tracked across PRs.
Usage: PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import os
import subprocess
import sys
import traceback

from . import common


def _subprocess_bench(name: str):
    """Benches needing the 4-device env var BEFORE jax init run as
    subprocesses (each writes its own BENCH_*.json)."""
    script = os.path.join(os.path.dirname(__file__), name)

    def run() -> None:
        res = subprocess.run([sys.executable, script], check=False)
        if res.returncode:
            raise RuntimeError(f"{name} exited {res.returncode}")

    return run


_distributed_subprocess = _subprocess_bench("distributed_bench.py")
_comm_subprocess = _subprocess_bench("comm_bench.py")


def main() -> None:
    from . import (table2_suite, table3_accuracy, fig2_overhead,
                   kernels_bench, binning_bench, accumulator_bench,
                   roofline_bench, moe_capacity_bench, partition_bench)
    sections = [
        ("table2 (suite stats)", table2_suite.run),
        ("table3 (625-case accuracy)", table3_accuracy.run),
        ("fig2 (prediction overhead)", fig2_overhead.run),
        ("kernels (pallas microbench)", kernels_bench.run),
        ("binning (binned vs global-pad)", binning_bench.run),
        ("accumulators (spa vs esc routes)", accumulator_bench.run),
        ("roofline (dry-run cells)", roofline_bench.run),
        ("moe capacity (beyond-paper)", moe_capacity_bench.run),
        ("partition (load balance)", partition_bench.run),
        ("distributed (plan/execute vs legacy)", _distributed_subprocess),
        ("comm (panel-gathered B vs replicated)", _comm_subprocess),
    ]
    common.reset_records()
    failed = 0
    for name, fn in sections:
        print(f"\n## {name}")
        try:
            fn()
        except Exception:
            failed += 1
            traceback.print_exc()
    out = os.path.join(os.path.dirname(__file__), "..", "BENCH_kernels.json")
    common.write_bench_json(os.path.abspath(out),
                            extra=dict(binning=binning_bench.summary(),
                                       accumulators=accumulator_bench.summary()))
    print(f"\nwrote {os.path.abspath(out)}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
