"""Benchmark harness: one module per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV rows (plus table sections as
comment/CSV blocks).  Usage: PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (table2_suite, table3_accuracy, fig2_overhead,
                   kernels_bench, roofline_bench, moe_capacity_bench,
                   partition_bench)
    sections = [
        ("table2 (suite stats)", table2_suite.run),
        ("table3 (625-case accuracy)", table3_accuracy.run),
        ("fig2 (prediction overhead)", fig2_overhead.run),
        ("kernels (pallas microbench)", kernels_bench.run),
        ("roofline (dry-run cells)", roofline_bench.run),
        ("moe capacity (beyond-paper)", moe_capacity_bench.run),
        ("partition (load balance)", partition_bench.run),
    ]
    failed = 0
    for name, fn in sections:
        print(f"\n## {name}")
        try:
            fn()
        except Exception:
            failed += 1
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
