"""Paper Table III + Section VI-A headline numbers: relative errors of the
reference design (e1), the symmetric FLOP predictor (ef), and the proposed
sampled-CR method (e2), over the 625-pair suite."""
from __future__ import annotations

import numpy as np

from .common import load_artifact, emit


def run():
    art = load_artifact("accuracy_625.json")
    if art is None:
        from repro.core import experiment
        names = [e.name for e in __import__(
            "repro.sparse.suite", fromlist=["SUITE"]).SUITE[:5]]
        art = experiment.run_all(names=names, verbose=False,
                                 out_path="/tmp/accuracy_mini.json")
    agg = art["aggregate"]
    cases = art["cases"]
    print("# Table III analogue: 20 representative cases")
    print("A,B,sample_num,CR,NNZ_C,e1_pct,ef_pct,e2_pct")
    idx = np.linspace(0, len(cases) - 1, 20).astype(int)
    for i in idx:
        c = cases[i]
        print(f"{c['A']},{c['B']},{c['sample_num']},{c['cr']:.2f},{c['nnz']},"
              f"{c['e1']*100:.2f},{c['ef']*100:.2f},{c['e2']*100:.2f}")
    print("# headline vs paper (paper: e1 8.12%/158%, e2 1.56%/25%, "
          "better 81.4%, corr 97.01%)")
    emit("accuracy.mean_abs_e1_pct", 0.0, f"{agg['mean_abs_e1']*100:.2f}")
    emit("accuracy.mean_abs_ef_pct", 0.0, f"{agg['mean_abs_ef']*100:.2f}")
    emit("accuracy.mean_abs_e2_pct", 0.0, f"{agg['mean_abs_e2']*100:.2f}")
    emit("accuracy.mean_abs_e3_minhash_pct", 0.0, f"{agg['mean_abs_e3']*100:.2f}")
    emit("accuracy.worst_abs_e1_pct", 0.0, f"{agg['worst_abs_e1']*100:.2f}")
    emit("accuracy.worst_abs_e2_pct", 0.0, f"{agg['worst_abs_e2']*100:.2f}")
    emit("accuracy.proposed_better_frac", 0.0,
         f"{agg['proposed_better_frac']:.4f}")
    emit("accuracy.corr_e1_ef", 0.0, f"{agg['corr_e1_ef']:.4f}")
    emit("accuracy.max_eq5_residual", 0.0, f"{agg['max_eq5_resid']:.2e}")


if __name__ == "__main__":
    run()
