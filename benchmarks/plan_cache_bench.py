"""Plan-cache quantization + overflow re-planning: the serving economics.

Measures, per suite family (ISSUE 4 acceptance):

  * **executor reuse across same-family different-seed pairs** — three
    tiers.  Without quantization only structure-identical plans share a key
    (reuse 0%).  With ``pop_quant=True`` the pow2-padded key lets members
    share whenever their bucket ladders coincide (band does; er/fem flip
    pow2 bands seed-to-seed; pl/rmat hub degrees are data-unstable) — at a
    measured ≤2× row padding.  With a ``PlanTemplate`` the family's bucket
    ladder is frozen and grown monotonically, so EVERY family reaches 100%
    reuse once the template stops growing (the ``steady`` rate, gated).
  * **serving reuse** (same structure, new values): must stay 100% / zero
    retraces with quantization on.
  * **re-planning overhead**: one under-allocated execute (safety=0, armed
    retry loop) vs one ample-capacity execute, cold cache both — what the
    realloc path costs when the prediction misses low.

Standalone::

    PYTHONPATH=src python benchmarks/plan_cache_bench.py [--quick]

Emits ``plancache.*`` CSV rows and writes ``BENCH_plan_cache.json`` at the
repo root (committed per PR).  ``--quick`` shrinks matrices for CI.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from repro.sparse import random as sprand
from repro.sparse.formats import CSR
from repro.core import plan as plan_mod

try:
    from .common import emit, reset_records, write_bench_json
except ImportError:   # invoked as a script
    from common import emit, reset_records, write_bench_json

_LAST: dict = {}
NPAIRS = 4


def _gen(fam: str, m: int, seed: int) -> tuple[CSR, CSR]:
    if fam == "er":
        return (sprand.erdos_renyi(m, m, 4, seed=seed),
                sprand.erdos_renyi(m, m, 3, seed=seed + 50))
    if fam == "pl":
        return (sprand.power_law(m, m, 5, 1.5, seed=seed),
                sprand.power_law(m, m, 4, 1.6, seed=seed + 50))
    if fam == "rmat":
        return (sprand.rmat(m, m, 5 * m, seed=seed),
                sprand.rmat(m, m, 4 * m, seed=seed + 50))
    if fam == "band":
        return (sprand.banded(m, m, 12, 16, seed=seed),
                sprand.banded(m, m, 10, 14, seed=seed + 50))
    if fam == "fem":
        return (sprand.banded(m // 2, m // 2, 48, 32, seed=seed),
                sprand.banded(m // 2, m // 2, 40, 30, seed=seed + 50))
    raise ValueError(fam)


def _revalue(m: CSR, seed: int) -> CSR:
    rng = np.random.default_rng(seed)
    return CSR(rpt=m.rpt.copy(), col=m.col.copy(),
               val=rng.standard_normal(m.nnz).astype(np.float32),
               shape=m.shape)


def _reuse_sweep(fam: str, m: int, pop_quant: bool) -> dict:
    """Plan+execute NPAIRS different-seed pairs of one family through one
    cache; count how many of the N-1 follow-up plans reuse an executable."""
    cache = plan_mod.PlanCache()
    keys, paddings, slots = [], [], []
    for k in range(NPAIRS):
        a, b = _gen(fam, m, seed=1000 + 10 * k)
        p = plan_mod.plan_spgemm(a, b, safety=1.3, pop_quant=pop_quant)
        out = plan_mod.execute(p, a, b, cache=cache)
        assert int(np.asarray(out.row_nnz).sum()) > 0
        keys.append(p.key)
        slots.append(int(p.alloc.total_capacity))
        if pop_quant:
            paddings.append(p.stats()["row_padding"])
    st = cache.stats()
    return dict(
        reuse_rate=round(st["hits"] / (NPAIRS - 1), 4),
        hits=st["hits"], misses=st["misses"], traces=st["traces"],
        distinct_keys=len(set(keys)),
        mean_slots=int(np.mean(slots)),
        row_padding=round(float(np.max(paddings)), 4) if paddings else 1.0,
    )


def _template_sweep(fam: str, m: int) -> dict:
    """Template-planned members: cold pass (template may grow, re-keying
    later members) then a steady pass over the same pairs — 100% reuse and
    zero retraces once the family profile has stopped growing."""
    cache = plan_mod.PlanCache()
    a0, b0 = _gen(fam, m, seed=1000)
    tpl = plan_mod.PlanTemplate.from_plan(
        plan_mod.plan_spgemm(a0, b0, safety=1.3, pop_quant=True))
    for k in range(NPAIRS):
        a, b = _gen(fam, m, seed=1000 + 10 * k)
        p = plan_mod.plan_spgemm(a, b, safety=1.3, template=tpl)
        plan_mod.execute(p, a, b, cache=cache)
    cold = cache.stats()
    paddings = []
    for k in range(NPAIRS):
        a, b = _gen(fam, m, seed=1000 + 10 * k)
        p = plan_mod.plan_spgemm(a, b, safety=1.3, template=tpl)
        plan_mod.execute(p, a, b, cache=cache)
        real = max(1, sum(bk.n_rows for bk in p.binning.buckets))
        paddings.append(sum(p.local_populations()) / real)
    steady = cache.stats()
    return dict(
        cold_reuse=round(cold["hits"] / (NPAIRS - 1), 4),
        steady_reuse=round((steady["hits"] - cold["hits"]) / NPAIRS, 4),
        steady_retraces=steady["traces"] - cold["traces"],
        growths=tpl.growths,
        executors=steady["size"],
        row_padding=round(float(np.max(paddings)), 4),
    )


def _serving_sweep(fam: str, m: int) -> dict:
    """Same structure, new values, quantization ON: 100% reuse, 0 retraces."""
    cache = plan_mod.PlanCache()
    a, b = _gen(fam, m, seed=1000)
    p1 = plan_mod.plan_spgemm(a, b, safety=1.3, pop_quant=True)
    plan_mod.execute(p1, a, b, cache=cache)
    t0 = cache.stats()["traces"]
    a2, b2 = _revalue(a, 91), _revalue(b, 92)
    p2 = plan_mod.plan_spgemm(a2, b2, safety=1.3, pop_quant=True)
    plan_mod.execute(p2, a2, b2, cache=cache)
    return dict(same_key=p2.key == p1.key,
                retraces=cache.stats()["traces"] - t0,
                hits=cache.stats()["hits"])


def _replan_sweep(fam: str, m: int) -> dict:
    """Cold-cache one-shot: under-allocated execute (armed retry) vs ample
    execute — the cost of closing the realloc loop when prediction misses."""
    a, b = _gen(fam, m, seed=1000)

    p_u = plan_mod.plan_spgemm(a, b, safety=0.0, retry_safety=1.5)
    t0 = time.perf_counter()
    out_u = plan_mod.execute(p_u, a, b, cache=plan_mod.PlanCache())
    t_under = time.perf_counter() - t0

    p_a = plan_mod.plan_spgemm(a, b, safety=1.3, retry_safety=1.5,
                               sample_rows=p_u.sample_rows)
    t0 = time.perf_counter()
    out_a = plan_mod.execute(p_a, a, b, cache=plan_mod.PlanCache())
    t_ample = time.perf_counter() - t0

    stats_u, stats_a = p_u.stats(), p_a.stats()
    json.dumps([stats_u, stats_a])   # stats must stay JSON-serializable
    return dict(
        retry_rounds=p_u.retries,
        retried_buckets=len(p_u.retry_events),
        num_buckets=len(p_u.binning.buckets),
        overflow_after=int(out_u.overflow) + int(out_a.overflow) * 0,
        retry_us=round(t_under * 1e6, 1),
        ample_us=round(t_ample * 1e6, 1),
        retry_premium=round(t_under / max(t_ample, 1e-12), 3),
        ample_retries=p_a.retries,
        # §9 containment counters: the happy path must never degrade to the
        # exact-symbolic fallback (ample) and the legacy ladder must close
        # every overflow on its own (under-allocated, surface mode)
        degradations_under=len(stats_u["degradations"]),
        degradations_ample=len(stats_a["degradations"]),
        validation=stats_a["validation"],
    )


def run(quick: bool = False):
    _LAST.clear()
    m = 500 if quick else 2000
    for fam in ("er", "pl", "rmat", "band", "fem"):
        exact = _reuse_sweep(fam, m, pop_quant=False)
        quant = _reuse_sweep(fam, m, pop_quant=True)
        tmpl = _template_sweep(fam, m)
        serving = _serving_sweep(fam, m)
        replan = _replan_sweep(fam, m)
        emit(f"plancache.{fam}.reuse_exact.rate", exact["reuse_rate"] * 100,
             "same-family different-seed, exact keys")
        emit(f"plancache.{fam}.reuse_quant.rate", quant["reuse_rate"] * 100,
             "same-family different-seed, pow2-quantized keys")
        emit(f"plancache.{fam}.reuse_template.rate",
             tmpl["steady_reuse"] * 100,
             "same-family different-seed, template-planned (steady)")
        emit(f"plancache.{fam}.row_padding.x", quant["row_padding"],
             "pow2 population pad (≤2 by construction)")
        emit(f"plancache.{fam}.template_padding.x", tmpl["row_padding"],
             "template population pad (grown family profile)")
        emit(f"plancache.{fam}.serving_retraces.n", serving["retraces"],
             "same structure, new values, quantized")
        emit(f"plancache.{fam}.retry_premium.x", replan["retry_premium"],
             "under-allocated+retry vs ample, cold cache")
        _LAST[fam] = dict(exact=exact, quant=quant, template=tmpl,
                          serving=serving, replan=replan)


def summary() -> dict:
    return dict(_LAST)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true",
                   help="CI-sized matrices")
    args = p.parse_args(argv)
    reset_records()
    run(quick=args.quick)
    out = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "BENCH_plan_cache.json"))
    write_bench_json(out, extra=dict(plan_cache=summary(), quick=args.quick,
                                     npairs=NPAIRS))
    print(json.dumps(summary(), indent=1))
    print(f"wrote {out}")
    ok = True
    for fam, s in summary().items():
        if s["quant"]["row_padding"] > 2.0:
            print(f"FAIL: {fam} row padding {s['quant']['row_padding']} > 2x")
            ok = False
        if not s["serving"]["same_key"] or s["serving"]["retraces"]:
            print(f"FAIL: {fam} quantized serving pair retraced")
            ok = False
        if s["replan"]["overflow_after"]:
            print(f"FAIL: {fam} retry loop left overflow")
            ok = False
        if s["replan"]["degradations_ample"] or \
                s["replan"]["degradations_under"]:
            print(f"FAIL: {fam} happy path hit the exact-symbolic fallback "
                  f"(under={s['replan']['degradations_under']}, "
                  f"ample={s['replan']['degradations_ample']})")
            ok = False
        # every family must reach 100% reuse / zero retraces once its
        # template stops growing (pow2-key reuse without a template is
        # reported per family above: it holds only when the seed's bucket
        # ladder happens to coincide)
        if s["template"]["steady_reuse"] < 1.0 or \
                s["template"]["steady_retraces"]:
            print(f"FAIL: {fam} template steady reuse "
                  f"{s['template']['steady_reuse']} "
                  f"({s['template']['steady_retraces']} retraces)")
            ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
