"""Load-balance benchmark: predicted-NNZ partitioning vs FLOP partitioning
(the paper's load-balance application, measured as the straggler factor a
pod's shards would see on the accumulation work).

The effect requires per-row compression-ratio VARIANCE — a matrix whose rows
mix high-CR (FEM-like) and low-CR (ER-like) structure, which is where
FLOP-balanced shards mis-load by exactly the CR spread.  Uniform-CR suite
matrices are included as controls (speedup ≈ 1 expected)."""
from __future__ import annotations

import numpy as np

from repro.core import oracle, partition
from repro.sparse import random as sprand
from repro.sparse.formats import CSR
from repro.sparse.suite import get_matrix
from .common import emit


def _mixed_cr_matrix(seed: int = 0) -> CSR:
    """Top half: dense banded rows (CR≈15); bottom half: ER rows (CR≈1)."""
    m = 16_000
    top = sprand.banded(m // 2, m, 60, 34, seed=seed)
    bot = sprand.erdos_renyi(m // 2, m, 6, seed=seed + 1)
    rows = np.concatenate([
        np.repeat(np.arange(m // 2), top.row_nnz),
        np.repeat(np.arange(m // 2, m), bot.row_nnz)])
    cols = np.concatenate([top.col, bot.col])
    vals = np.concatenate([top.val, bot.val])
    return CSR.from_coo(rows, cols, vals, (m, m), dedup=False)


def run(num_parts: int = 256):
    print("# straggler factor (max/mean accumulation work across shards)")
    print("matrix,flop_balanced,pred_nnz_balanced,speedup")
    cases = [("mixed_cr_16k", _mixed_cr_matrix()),
             ("fem_24k_d64", get_matrix("fem_24k_d64")),
             ("rmat_60k", get_matrix("rmat_60k")),
             ("band_40k_d24", get_matrix("band_40k_d24"))]
    for name, a in cases:
        floprc, _ = oracle.flop_per_row(a, a)
        # stratified sampled-CR (beyond-paper): per-segment ratios — the
        # global-CR prediction is ∝ flopr and cannot rebalance mixed-CR rows
        pred = oracle.stratified_predict(a, a, seed=0)
        nnzr_true, _ = oracle.exact_structure(a, a)
        # shards bounded by FLOP vs by predicted nnzr; cost model = true nnzr
        p_flop = partition.balanced_contiguous(floprc, num_parts)
        p_pred = partition.balanced_contiguous(pred.structure, num_parts)
        w_f = np.add.reduceat(nnzr_true, p_flop.bounds[:-1].clip(0, len(nnzr_true) - 1))
        w_p = np.add.reduceat(nnzr_true, p_pred.bounds[:-1].clip(0, len(nnzr_true) - 1))
        imb_f = w_f.max() / max(w_f.mean(), 1e-9)
        imb_p = w_p.max() / max(w_p.mean(), 1e-9)
        print(f"{name},{imb_f:.3f},{imb_p:.3f},{imb_f/imb_p:.3f}")
        emit(f"partition.straggler_speedup.{name}", 0.0,
             f"{imb_f/imb_p:.3f}")


if __name__ == "__main__":
    run()
