"""Degree-binned vs global-pad pipeline on skewed and uniform families.

The acceptance metric for the binning engine (core/binning.py): on the
power-law family the binned pipeline must process ≥2x fewer expanded-buffer
lanes AND run faster in interpret mode than padding every row to the global
``(DA, DB)``.  Banded/FEM families are the control — near-uniform degrees,
so binning should neither help nor hurt there.

Emits ``binning.*`` CSV rows (captured into BENCH_kernels.json by run.py)
plus a machine-readable summary via ``summary()``.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.sparse import random as sprand
from repro.sparse.suite import degree_skew
from repro.core import predictor, spgemm
from repro.core import plan as plan_mod
from repro.core.flop import flop_per_row
from .common import timeit, emit

_LAST: dict = {}


def _cases():
    return [
        ("pl", sprand.power_law(3000, 3000, 5, 1.5, seed=11),
         sprand.power_law(3000, 3000, 4, 1.6, seed=12)),
        ("band", sprand.banded(2000, 2000, 12, 16, seed=13),
         sprand.banded(2000, 2000, 10, 14, seed=14)),
    ]


def run():
    _LAST.clear()
    for fam, a, b in _cases():
        mda, mdb = int(a.row_nnz.max()), int(b.row_nnz.max())
        skew = degree_skew(a)

        rows = predictor.draw_sample_rows(
            jax.random.PRNGKey(0), a.nrows, predictor.static_sample_num(a.nrows))

        # binned arms run through the unified plan/execute pipeline
        # (DESIGN.md §6) — plan_spgemm subsumes build_plan + the binned
        # allocation, and execute is the cache-served binned executor
        sp = plan_mod.plan_spgemm(a, b, safety=1.5,
                                  sample_rows=np.asarray(rows))
        plan = sp.binning
        ad, bd = sp.to_device(a, "a"), sp.to_device(b, "b")

        t_pred_g = timeit(lambda: jax.block_until_ready(
            predictor.proposed_predict(ad, bd, rows, mda, mdb).nnz_total))
        t_pred_b = timeit(lambda: jax.block_until_ready(
            predictor.proposed_predict_binned(ad, bd, rows, plan).nnz_total))

        floprc, _ = flop_per_row(ad, bd)
        pred = predictor.proposed_predict(ad, bd, rows, mda, mdb)
        alloc = predictor.AllocationPlan.from_prediction(
            np.asarray(pred.structure), np.asarray(floprc), safety=1.5)
        balloc = sp.alloc

        t_num_g = timeit(lambda: jax.block_until_ready(
            spgemm.spgemm(ad, bd, row_capacity=alloc.row_capacity,
                          max_deg_a=mda, max_deg_b=mdb,
                          block_rows=256).overflow))
        t_num_b = timeit(lambda: jax.block_until_ready(
            plan_mod.execute(sp, ad, bd).overflow))

        emit(f"binning.{fam}.predict_global.us", t_pred_g * 1e6, "jnp")
        emit(f"binning.{fam}.predict_binned.us", t_pred_b * 1e6, "binned")
        emit(f"binning.{fam}.numeric_global.us", t_num_g * 1e6, "jnp")
        emit(f"binning.{fam}.numeric_binned.us", t_num_b * 1e6, "binned")
        emit(f"binning.{fam}.lane_reduction.x", plan.lane_reduction, "plan")
        emit(f"binning.{fam}.numeric_speedup.x", t_num_g / max(t_num_b, 1e-12),
             "wallclock")
        _LAST[fam] = dict(
            skew=skew, plan=plan.stats(),
            lane_reduction=round(plan.lane_reduction, 3),
            predict_global_us=round(t_pred_g * 1e6, 1),
            predict_binned_us=round(t_pred_b * 1e6, 1),
            numeric_global_us=round(t_num_g * 1e6, 1),
            numeric_binned_us=round(t_num_b * 1e6, 1),
            numeric_speedup=round(t_num_g / max(t_num_b, 1e-12), 3),
            row_capacity_global=alloc.row_capacity,
            bucket_capacities=list(balloc.bucket_capacities),
        )


def summary() -> dict:
    """Machine-readable results of the last run() (for BENCH_kernels.json)."""
    return dict(_LAST)


if __name__ == "__main__":
    run()
    import json
    print(json.dumps(summary(), indent=1))
