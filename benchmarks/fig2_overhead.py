"""Paper Fig. 2: relative execution time of the two prediction tasks vs the
full SpGEMM library run, on the matrix-square benchmark.

Our 'library' is the vectorized host SpGEMM (oracle.spgemm — the analogue of
BRMerge-Precise in this reproduction); the two tasks are computing the FLOP
per output row (Algorithm 1) and predicting Z2* (Algorithm 2).
Paper result: computing FLOP 1.68% (≤4.12%), predicting Z2* 0.72% (≤1.89%).
"""
from __future__ import annotations

import numpy as np

from repro.core import oracle
from repro.sparse.suite import SUITE, get_matrix
from .common import timeit, emit

# matrix-square benchmark on a representative CR spread (full 25 is slow on
# the shared CI core; families cover the Fig. 2 x-axis)
BENCH = ["er_100k_d4", "pl_80k_d6", "rmat_60k", "band_40k_d24",
         "fem_24k_d64", "femblk_20k"]


def run(names=None):
    names = names or BENCH
    print("# Fig. 2 analogue: prediction overhead vs full SpGEMM "
          "(matrix-square)")
    print("matrix,flop_pct,predict_pct,spgemm_s")
    ratios_f, ratios_p = [], []
    for name in names:
        a = get_matrix(name)
        floprc, total_flop = oracle.flop_per_row(a, a)
        rows = oracle.sample_rows(a.nrows, seed=0)

        t_flop = timeit(lambda: oracle.flop_per_row(a, a))
        t_pred = timeit(lambda: oracle.exact_sampled_nnz(a, a, rows))
        t_full = timeit(lambda: oracle.spgemm(a, a), warmup=0, iters=1)
        rf, rp = t_flop / t_full * 100, t_pred / t_full * 100
        ratios_f.append(rf)
        ratios_p.append(rp)
        print(f"{name},{rf:.2f},{rp:.2f},{t_full:.3f}")
    emit("fig2.mean_flop_pct", 0.0, f"{np.mean(ratios_f):.2f}")
    emit("fig2.max_flop_pct", 0.0, f"{np.max(ratios_f):.2f}")
    emit("fig2.mean_predict_pct", 0.0, f"{np.mean(ratios_p):.2f}")
    emit("fig2.max_predict_pct", 0.0, f"{np.max(ratios_p):.2f}")


if __name__ == "__main__":
    run()
