"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import json
import os
import time

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def art_path(name: str) -> str:
    return os.path.abspath(os.path.join(ART, name))


def load_artifact(name: str):
    p = art_path(name)
    if os.path.exists(p):
        with open(p) as f:
            return json.load(f)
    return None


def timeit(fn, *, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds (paper: average of runs after one warm-up)."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


RECORDS: list[dict] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.3f},{derived}")
    RECORDS.append(dict(name=name, us_per_call=round(float(us_per_call), 3),
                        variant=derived))


def reset_records() -> None:
    RECORDS.clear()


def write_bench_json(path: str, extra: dict | None = None) -> None:
    """Persist every emitted record (+ optional extra sections) as JSON —
    the cross-PR perf trajectory artifact (BENCH_kernels.json).

    The write is atomic (temp file + ``os.replace`` in the target dir): a
    bench that dies mid-write leaves the previous artifact intact instead
    of a truncated JSON that poisons the perf trajectory."""
    payload = dict(records=list(RECORDS))
    if extra:
        payload.update(extra)
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
