"""Mini dry-run: the production lowering path on 8 placeholder devices.

Runs in a SUBPROCESS because the 8-device XLA_FLAGS must be set before jax
initializes — the main test process keeps its single device (conftest).
"""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import get_smoke_config
from repro.models import transformer as tmod
from repro.models.schema import abstract_params
from repro.models.sharding import make_rules, specs_from_schema
from repro.train import optimizer as opt_mod
from repro.train.train_loop import make_train_step
from repro.roofline import hlo_cost

assert len(jax.devices()) == 8, jax.devices()
mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_smoke_config("deepseek-v3-671b")      # MLA + MoE: hardest wiring
schema = tmod.build_schema(cfg, mesh_model=4)
rules = make_rules(cfg, mesh_model=4, multi_pod=False, fsdp=True)
pspecs = specs_from_schema(schema, rules)
params_abs = abstract_params(schema, dtype=jnp.float32)
sh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs,
                            is_leaf=lambda x: isinstance(x, P))
oc = opt_mod.AdamWConfig()
opt_abs = jax.eval_shape(lambda p: opt_mod.init_state(oc, p), params_abs)
batch = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
         "labels": jax.ShapeDtypeStruct((4, 32), jnp.int32)}
bsh = {"tokens": NamedSharding(mesh, P("data", None)),
       "labels": NamedSharding(mesh, P("data", None))}
step = make_train_step(cfg, oc)
with mesh:
    lowered = jax.jit(step, in_shardings=(sh, None, bsh),
                      out_shardings=(sh, None, None)).lower(
        params_abs, opt_abs, batch)
compiled = lowered.compile()
mem = compiled.memory_analysis()
parsed = hlo_cost.analyze(compiled.as_text())
print(json.dumps(dict(ok=True, flops=parsed["flops"],
                      coll=parsed["collective_bytes"],
                      temp=mem.temp_size_in_bytes)))
"""


@pytest.mark.slow
def test_mini_dryrun_8_devices():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"]
    assert rec["flops"] > 0
    assert rec["coll"] > 0          # sharded training must communicate
