"""Training loop + checkpoint/restart fault-tolerance behaviour."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import smoke_registry
from repro.models import transformer as T
from repro.models.schema import init_params
from repro.train import optimizer as opt_mod
from repro.train.train_loop import make_train_step, cross_entropy
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.ckpt import checkpoint as ckpt


def test_schedule_shape():
    oc = opt_mod.AdamWConfig(lr_peak=1e-3, lr_min=1e-4, warmup_steps=10,
                             total_steps=100)
    lrs = [float(opt_mod.schedule(oc, jnp.asarray(s))) for s in
           [0, 5, 10, 50, 99]]
    assert lrs[0] < lrs[1] < lrs[2] == pytest.approx(1e-3, rel=1e-2)
    assert lrs[3] > lrs[4] >= 1e-4 - 1e-9


def test_cross_entropy_matches_manual():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.standard_normal((2, 4, 7)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 7, (2, 4)), jnp.int32)
    got = float(cross_entropy(logits, labels))
    p = jax.nn.log_softmax(logits, -1)
    want = -float(jnp.take_along_axis(p, labels[..., None], -1).mean())
    assert got == pytest.approx(want, rel=1e-5)


def test_loss_decreases_on_learnable_stream():
    """End-to-end: tiny dense model on the structured synthetic stream."""
    cfg = smoke_registry()["phi3-mini-3.8b"]
    params = init_params(T.build_schema(cfg, 1), jax.random.PRNGKey(0),
                         jnp.float32)
    oc = opt_mod.AdamWConfig(lr_peak=3e-3, warmup_steps=5, total_steps=60,
                             weight_decay=0.0)
    state = opt_mod.init_state(oc, params)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=8, seed=0))
    step = jax.jit(make_train_step(cfg, oc))
    losses = []
    for i in range(40):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, state, m = step(params, state, b)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses[::8]


def test_grad_accum_equivalence():
    cfg = smoke_registry()["starcoder2-7b"]
    params = init_params(T.build_schema(cfg, 1), jax.random.PRNGKey(1),
                         jnp.float32)
    oc = opt_mod.AdamWConfig(lr_peak=1e-3, warmup_steps=1, total_steps=10)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=8, seed=1))
    b = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    s1 = opt_mod.init_state(oc, params)
    p1, _, m1 = make_train_step(cfg, oc, accum=1)(params, s1, b)
    s2 = opt_mod.init_state(oc, params)
    p2, _, m2 = make_train_step(cfg, oc, accum=4)(params, s2, b)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    for a, c in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32),
                                   rtol=2e-3, atol=2e-5)


def test_checkpoint_roundtrip(tmp_path):
    cfg = smoke_registry()["xlstm-125m"]
    params = init_params(T.build_schema(cfg, 1), jax.random.PRNGKey(2),
                         jnp.float32)
    oc = opt_mod.AdamWConfig()
    state = opt_mod.init_state(oc, params)
    d = str(tmp_path / "ck")
    ckpt.save(d, 7, (params, state), extra={"seed": 3})
    (p2, s2), extra, step = ckpt.restore(d, (params, state))
    assert step == 7 and extra["seed"] == 3
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_retention_and_latest(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"w": jnp.ones((3,))}
    for s in [1, 2, 3, 4, 5]:
        ckpt.save(d, s, tree, keep=2)
    assert ckpt.latest_step(d) == 5
    kept = sorted(os.listdir(d))
    assert len(kept) == 2


def test_checkpoint_atomicity_ignores_tmp(tmp_path):
    """A crashed (un-renamed) .tmp dir must be invisible to restore."""
    d = str(tmp_path / "ck")
    tree = {"w": jnp.ones((3,))}
    ckpt.save(d, 1, tree)
    os.makedirs(os.path.join(d, "step_0000000009.tmp"))  # simulated crash
    assert ckpt.latest_step(d) == 1
    _, _, step = ckpt.restore(d, tree)
    assert step == 1


def test_restart_reproduces_batch_stream():
    """Pipeline is pure in (seed, step): restart at step k gives same data."""
    dc = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=9)
    a = SyntheticLM(dc).batch(5)
    b = SyntheticLM(dc).batch(5)   # "restarted" pipeline
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_host_sharded_pipeline_partitions_batch():
    dc = DataConfig(vocab_size=100, seq_len=8, global_batch=8, seed=4)
    h0 = SyntheticLM(dc, host_index=0, host_count=2).batch(0)
    h1 = SyntheticLM(dc, host_index=1, host_count=2).batch(0)
    assert h0["tokens"].shape == (4, 8)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_async_checkpoint_roundtrip(tmp_path):
    """save_async returns immediately; wait_async + restore sees the data."""
    d = str(tmp_path / "ck")
    tree = {"w": jnp.arange(16.0), "b": jnp.ones((3, 3))}
    ckpt.save_async(d, 5, tree, extra={"k": 1})
    ckpt.wait_async(d)
    (restored), extra, step = ckpt.restore(d, tree)
    assert step == 5 and extra["k"] == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


def test_elastic_restore_new_sharding(tmp_path):
    """Mesh-agnostic restore: lay the checkpoint out for a NEW mesh/sharding
    (elastic restart path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    d = str(tmp_path / "ck")
    tree = {"w": jnp.arange(32.0).reshape(4, 8)}
    ckpt.save(d, 1, tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _, _ = ckpt.restore(d, tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


@pytest.mark.parametrize("arch", ["xlstm-125m", "deepseek-v3-671b"])
def test_loss_decreases_other_families(arch):
    """Convergence smoke for the SSM and MoE families (phi3 covers dense)."""
    cfg = smoke_registry()[arch]
    params = init_params(T.build_schema(cfg, 1), jax.random.PRNGKey(0),
                         jnp.float32)
    oc = opt_mod.AdamWConfig(lr_peak=2e-3, warmup_steps=5, total_steps=40,
                             weight_decay=0.0)
    state = opt_mod.init_state(oc, params)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=4, seed=0))
    step = jax.jit(make_train_step(cfg, oc))
    losses = []
    for i in range(25):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, state, m = step(params, state, b)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses[::6]
