"""Property tests for operand validation (DESIGN.md §9).

``validate_csr`` must accept every matrix the sparse suite generates, and
reject every single-field mutation — swapped columns, truncated rpt,
injected NaN, out-of-range column index, duplicated column — with an
:class:`~repro.core.errors.OperandValidationError` whose context pinpoints
the offending field.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal CI image — deterministic tests must still run
    from hypothesis_shim import given, settings, st

from repro.sparse import random as sprand
from repro.sparse.formats import CSR
from repro.core.errors import (OperandValidationError, PlanMismatchError,
                               SpgemmError)
from repro.core.validate import validate_csr, validate_pair


def _family(fam: str, m: int, seed: int) -> CSR:
    if fam == "er":
        return sprand.erdos_renyi(m, m, 4, seed=seed)
    if fam == "pl":
        return sprand.power_law(m, m, 5, 1.5, seed=seed)
    if fam == "rmat":
        return sprand.rmat(m, m, 5 * m, seed=seed)
    if fam == "band":
        return sprand.banded(m, m, 12, 16, seed=seed)
    return sprand.banded(m // 2, m // 2, 48, 32, seed=seed)   # fem


# --------------------------------------------------------------------------- #
# acceptance: everything the suite generates is valid
# --------------------------------------------------------------------------- #
@given(st.integers(0, 1000), st.integers(40, 300))
@settings(max_examples=15, deadline=None)
def test_accepts_every_suite_matrix(seed, m):
    for fam in ("er", "pl", "rmat", "band", "fem"):
        validate_csr(_family(fam, m, seed), name=fam)


def test_accepts_empty_and_degenerate():
    validate_csr(CSR.from_coo(np.zeros(0), np.zeros(0), None, (5, 7)))
    validate_csr(CSR.from_coo(np.zeros(0), np.zeros(0), None, (0, 0)))
    # empty leading/trailing rows exercise the row-boundary mask edges
    validate_csr(CSR.from_coo(np.array([2, 2]), np.array([1, 3]),
                              None, (6, 4)))


def test_accepts_duplicates_when_allowed():
    m = CSR.from_coo(np.array([0, 0]), np.array([2, 2]),
                     np.ones(2, np.float32), (2, 4), dedup=False,
                     validate=False)
    validate_csr(m, allow_duplicates=True)
    with pytest.raises(OperandValidationError, match="duplicate"):
        validate_csr(m)


# --------------------------------------------------------------------------- #
# rejection: every single-field mutation raises with the right context
# --------------------------------------------------------------------------- #
def _mutations(m: CSR):
    """(name, mutated CSR, expected-context field, message regex)."""
    assert m.nnz >= 4
    r = int(np.flatnonzero(np.diff(m.rpt) >= 2)[0])   # a row with >= 2 entries
    lo = int(m.rpt[r])
    out = []

    swapped = m.col.copy()
    swapped[lo], swapped[lo + 1] = swapped[lo + 1], swapped[lo]
    out.append(("swapped_cols",
                CSR(m.rpt, swapped, m.val, m.shape), "col", "unsorted"))

    out.append(("truncated_rpt",
                CSR(m.rpt[:-1], m.col, m.val, m.shape), "rpt", "length"))

    nanval = m.val.copy()
    nanval[lo] = np.nan
    out.append(("nan_val",
                CSR(m.rpt, m.col, nanval, m.shape), "val", "non-finite"))

    oob = m.col.copy()
    oob[lo] = m.ncols + 3
    out.append(("oob_col",
                CSR(m.rpt, oob, m.val, m.shape), "col", "out of range"))

    dup = m.col.copy()
    dup[lo + 1] = dup[lo]
    out.append(("dup_col",
                CSR(m.rpt, dup, m.val, m.shape), "col", "duplicate"))

    broken = m.rpt.copy()
    broken[1] = broken[2] + 1          # non-monotone interior pointer
    out.append(("nonmonotone_rpt",
                CSR(broken, m.col, m.val, m.shape), "rpt", "monotone"))
    return out


@given(st.integers(0, 500))
@settings(max_examples=10, deadline=None)
def test_rejects_every_mutation(seed):
    m = _family("er", 80, seed)
    for name, bad, field, pattern in _mutations(m):
        with pytest.raises(OperandValidationError, match=pattern) as exc:
            validate_csr(bad, name=name)
        assert exc.value.context["field"] == field, name
        assert exc.value.context["operand"] == name
        assert isinstance(exc.value, ValueError)     # back-compat contract


def test_mutation_pinpoints_row_and_index():
    m = _family("band", 60, seed=7)
    r = int(np.flatnonzero(np.diff(m.rpt) >= 1)[2])
    e = int(m.rpt[r])
    oob = m.col.copy()
    oob[e] = m.ncols
    with pytest.raises(OperandValidationError) as exc:
        validate_csr(CSR(m.rpt, oob, m.val, m.shape))
    assert exc.value.context["index"] == e
    assert exc.value.context["row"] == r
    assert exc.value.context["observed"] == m.ncols
    assert exc.value.context["planned"] == m.ncols


def test_validate_pair_shape_mismatch():
    a = _family("er", 40, seed=1)
    b = _family("er", 50, seed=2)
    with pytest.raises(OperandValidationError, match="incompatible"):
        validate_pair(a, b)


def test_from_coo_rejects_bad_triplets():
    with pytest.raises(OperandValidationError, match="out of range"):
        CSR.from_coo(np.array([0, 9]), np.array([0, 1]), None, (3, 3))
    with pytest.raises(OperandValidationError, match="out of range"):
        CSR.from_coo(np.array([0, 1]), np.array([0, -2]), None, (3, 3))
    with pytest.raises(OperandValidationError, match="non-finite"):
        CSR.from_coo(np.array([0, 1]), np.array([0, 1]),
                     np.array([1.0, np.inf], np.float32), (3, 3))
    # opt-out keeps the paper's "values are arbitrary" escape hatch
    CSR.from_coo(np.array([0, 1]), np.array([0, 1]),
                 np.array([1.0, np.inf], np.float32), (3, 3),
                 validate=False)


def test_error_taxonomy_hierarchy():
    # every typed error is a SpgemmError and a ValueError (existing
    # pytest.raises(ValueError) pins keep passing across the conversion)
    for cls in (OperandValidationError, PlanMismatchError):
        assert issubclass(cls, SpgemmError)
        assert issubclass(cls, ValueError)
    e = OperandValidationError("msg", field="col", index=3, observed=9)
    assert "field='col'" in str(e) and "index=3" in str(e) and "msg" in str(e)
    assert e.context == dict(field="col", index=3, observed=9)
