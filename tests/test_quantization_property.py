"""Hypothesis property tests for plan-cache key quantization (DESIGN.md §7).

The quantization contract: pow2-padded key components collide **iff** the
underlying quantities fall in the same pow2 band, padding never exceeds 2×,
and a quantized plan's execution is bitwise-equal to the unquantized plan's
on ``row_nnz``/``col`` (values to float tolerance — accumulation order is
unchanged, so in practice they are bitwise too)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal CI image — deterministic tests must still run
    from hypothesis_shim import given, settings, st

from repro.sparse import random as sprand
from repro.core import binning, plan as plan_mod


# --------------------------------------------------------------------------- #
# ceil_pow2: the quantizer itself
# --------------------------------------------------------------------------- #
@given(st.integers(1, 1 << 20), st.integers(1, 1 << 20))
@settings(max_examples=60, deadline=None)
def test_pow2_keys_collide_iff_same_band(n1, n2):
    """Padded populations collide exactly when the real populations share a
    pow2 band (band = ceil(log2 n)) — the hit-rate guarantee AND the
    no-false-sharing guarantee of the quantized key."""
    same_band = (max(0, n1 - 1).bit_length() == max(0, n2 - 1).bit_length())
    assert (binning.ceil_pow2(n1) == binning.ceil_pow2(n2)) == same_band


@given(st.integers(1, 1 << 20))
@settings(max_examples=40, deadline=None)
def test_pow2_padding_bounded_by_2x(n):
    p = binning.ceil_pow2(n)
    assert n <= p < 2 * n or (n == p == 1)
    assert p & (p - 1) == 0


# --------------------------------------------------------------------------- #
# quantized plans: key structure and padding bounds
# --------------------------------------------------------------------------- #
def _key_buckets(plan):
    """The per-bucket (signature, population, capacity) tuples of the key."""
    return plan.key[-1]


@given(st.integers(0, 10_000), st.integers(2, 8), st.integers(60, 300))
@settings(max_examples=10, deadline=None)
def test_quantized_key_pads_populations_and_caps_pow2(seed, d, m):
    a = sprand.erdos_renyi(m, m, d, seed=seed)
    b = sprand.erdos_renyi(m, m, max(2, d - 1), seed=seed + 1)
    u = plan_mod.plan_spgemm(a, b, safety=2.0,
                             deg_align=binning.POW2_DEG_ALIGN)
    q = plan_mod.plan_spgemm(a, b, safety=2.0, pop_quant=True,
                             sample_rows=u.sample_rows)
    # same degree rounding → same bucket partition; the quantized key holds
    # each bucket's pow2-padded population and pow2 capacity
    assert len(u.binning.buckets) == len(q.binning.buckets)
    for (sig_u, pop_u, cap_u), (sig_q, pop_q, cap_q) in zip(
            _key_buckets(u), _key_buckets(q)):
        assert sig_q == sig_u
        assert pop_q == binning.ceil_pow2(pop_u)
        assert pop_u <= pop_q < 2 * max(1, pop_u) or pop_u == pop_q == 1
        assert cap_q == binning.ceil_pow2(cap_u)
    # total row padding ≤ 2×
    assert q.stats()["row_padding"] <= 2.0


@given(st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_quantized_execution_bitwise_equal_on_row_nnz_col(seed):
    """Padding rows (repeat-last fill, masked at assembly) must not change
    the result: quantized execute == unquantized execute on row_nnz/col
    bitwise, values to float tolerance, overflow identical."""
    rng = np.random.default_rng(seed)
    m = int(rng.integers(50, 250))
    fam = seed % 3
    if fam == 0:
        a = sprand.erdos_renyi(m, m, int(rng.integers(2, 7)), seed=seed)
        b = sprand.erdos_renyi(m, m, int(rng.integers(2, 7)), seed=seed + 1)
    elif fam == 1:
        a = sprand.power_law(m, m, 4, 1.5, seed=seed)
        b = sprand.power_law(m, m, 3, 1.6, seed=seed + 1)
    else:
        a = sprand.banded(m, m, int(rng.integers(4, 12)), 8, seed=seed)
        b = sprand.banded(m, m, int(rng.integers(4, 12)), 6, seed=seed + 1)
    cache = plan_mod.PlanCache()
    u = plan_mod.plan_spgemm(a, b, safety=2.0,
                             deg_align=binning.POW2_DEG_ALIGN)
    q = plan_mod.plan_spgemm(a, b, safety=2.0, pop_quant=True,
                             sample_rows=u.sample_rows)
    ou = plan_mod.execute(u, a, b, cache=cache)
    oq = plan_mod.execute(q, a, b, cache=cache)
    np.testing.assert_array_equal(np.asarray(oq.row_nnz),
                                  np.asarray(ou.row_nnz))
    assert int(oq.overflow) == int(ou.overflow)
    cu = plan_mod.reassemble(u, ou, on_overflow="ignore")
    cq = plan_mod.reassemble(q, oq, on_overflow="ignore")
    np.testing.assert_array_equal(cq.rpt, cu.rpt)
    np.testing.assert_array_equal(cq.col, cu.col)
    np.testing.assert_allclose(cq.val, cu.val, rtol=1e-6, atol=1e-6)


def test_same_structure_revalued_pair_shares_quantized_executor():
    """The serving scenario survives quantization: same pattern + new values
    → same quantized key, zero retraces."""
    a = sprand.banded(300, 300, 8, 12, seed=31)
    rng = np.random.default_rng(1)
    a2 = type(a)(rpt=a.rpt.copy(), col=a.col.copy(),
                 val=rng.standard_normal(a.nnz).astype(np.float32),
                 shape=a.shape)
    cache = plan_mod.PlanCache()
    p1 = plan_mod.plan_spgemm(a, a, safety=2.0, pop_quant=True)
    plan_mod.execute(p1, a, a, cache=cache)
    t = cache.stats()["traces"]
    p2 = plan_mod.plan_spgemm(a2, a2, safety=2.0, pop_quant=True)
    assert p2.key == p1.key
    plan_mod.execute(p2, a2, a2, cache=cache)
    assert cache.stats()["traces"] == t
    assert cache.stats()["hits"] >= 1


def test_quantized_and_unquantized_keys_never_collide():
    """A plan whose populations happen to be pow2 already must not collide
    with a quantized plan (the executors differ: masked vs unmasked)."""
    a = sprand.banded(256, 256, 6, 8, seed=3)
    u = plan_mod.plan_spgemm(a, a, safety=2.0,
                             deg_align=binning.POW2_DEG_ALIGN)
    q = plan_mod.plan_spgemm(a, a, safety=2.0, pop_quant=True,
                             sample_rows=u.sample_rows)
    assert u.key != q.key


# --------------------------------------------------------------------------- #
# plan templates: the family-level compile contract (DESIGN.md §7)
# --------------------------------------------------------------------------- #
@given(st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_template_planned_execution_matches_direct_plan(seed):
    """Planning against a template re-bins rows under the template's (≥)
    bounds — the result must stay bitwise-equal to a directly-planned
    execution on row_nnz/col (values to float tolerance)."""
    rng = np.random.default_rng(seed)
    m = int(rng.integers(60, 250))
    fam = seed % 3
    if fam == 0:
        gen = lambda s: sprand.erdos_renyi(m, m, 4, seed=s)
    elif fam == 1:
        gen = lambda s: sprand.power_law(m, m, 4, 1.5, seed=s)
    else:
        gen = lambda s: sprand.banded(m, m, 8, 10, seed=s)
    cache = plan_mod.PlanCache()
    tpl = plan_mod.PlanTemplate.from_plan(
        plan_mod.plan_spgemm(gen(seed), gen(seed + 1), safety=2.0,
                             pop_quant=True))
    a, b = gen(seed + 2), gen(seed + 3)
    t = plan_mod.plan_spgemm(a, b, safety=2.0, template=tpl)
    d = plan_mod.plan_spgemm(a, b, safety=2.0, sample_rows=t.sample_rows)
    ot = plan_mod.execute(t, a, b, cache=cache)
    od = plan_mod.execute(d, a, b, cache=cache)
    np.testing.assert_array_equal(np.asarray(ot.row_nnz),
                                  np.asarray(od.row_nnz))
    ct = plan_mod.reassemble(t, ot, on_overflow="ignore")
    cd = plan_mod.reassemble(d, od, on_overflow="ignore")
    np.testing.assert_array_equal(ct.rpt, cd.rpt)
    np.testing.assert_array_equal(ct.col, cd.col)
    np.testing.assert_allclose(ct.val, cd.val, rtol=1e-5, atol=1e-5)


def test_template_growth_is_monotone_and_converges():
    """Once a member has grown the template, re-planning ANY already-seen
    member changes nothing (same key, no growth, zero retraces)."""
    gen = lambda s: (sprand.erdos_renyi(400, 400, 4, seed=s),
                     sprand.erdos_renyi(400, 400, 3, seed=s + 50))
    cache = plan_mod.PlanCache()
    tpl = plan_mod.PlanTemplate.from_plan(
        plan_mod.plan_spgemm(*gen(0), safety=1.3, pop_quant=True))
    members = [gen(i) for i in range(4)]
    for a, b in members:
        plan_mod.execute(plan_mod.plan_spgemm(a, b, safety=1.3, template=tpl),
                         a, b, cache=cache)
    g = tpl.growths
    t = cache.stats()["traces"]
    keys = set()
    for a, b in members:
        p = plan_mod.plan_spgemm(a, b, safety=1.3, template=tpl)
        plan_mod.execute(p, a, b, cache=cache)
        keys.add(p.key)
    assert tpl.growths == g, "re-planning a seen member grew the template"
    assert cache.stats()["traces"] == t, "steady-state member retraced"
    assert len(keys) == 1, "steady-state members landed on different keys"


def test_template_distributed_keys_shared_after_warmup():
    """num_shards planning (no mesh needed) through a template: steady-state
    members share the distributed key too."""
    gen = lambda s: (sprand.banded(300, 300, 10, 12, seed=s),
                     sprand.banded(300, 300, 8, 10, seed=s + 50))
    tpl = plan_mod.PlanTemplate.from_plan(
        plan_mod.plan_spgemm(*gen(0), safety=1.3, pop_quant=True))
    members = [gen(i) for i in range(3)]
    for a, b in members:                      # warm the dist profile
        plan_mod.plan_spgemm(a, b, safety=1.3, template=tpl, num_shards=4)
    keys = {plan_mod.plan_spgemm(a, b, safety=1.3, template=tpl,
                                 num_shards=4).key for a, b in members}
    assert len(keys) == 1


def test_template_rejects_mismatched_shapes_and_unquantized_source():
    a = sprand.banded(200, 200, 6, 8, seed=1)
    p = plan_mod.plan_spgemm(a, a, safety=2.0, pop_quant=True)
    tpl = plan_mod.PlanTemplate.from_plan(p)
    small = sprand.banded(100, 100, 6, 8, seed=2)
    with pytest.raises(ValueError, match="shapes"):
        plan_mod.plan_spgemm(small, small, template=tpl)
    u = plan_mod.plan_spgemm(a, a, safety=2.0)
    with pytest.raises(ValueError, match="pop_quant"):
        plan_mod.PlanTemplate.from_plan(u)
