"""Hypothesis property tests on the predictor's invariants."""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal CI image — deterministic tests must still run
    from hypothesis_shim import given, settings, st

from repro.sparse import random as sprand
from repro.core import oracle
from repro.kernels.sortnet import next_pow2
import jax.numpy as jnp


@given(st.integers(0, 1000), st.integers(2, 10), st.integers(60, 400))
@settings(max_examples=20, deadline=None)
def test_prediction_positive_and_bounded(seed, d, m):
    """Z2* ∈ (0, FLOP]: CR* ≥ 1 always (distinct ≤ products)."""
    a = sprand.erdos_renyi(m, m, d, seed)
    rows = oracle.sample_rows(m, seed)
    p = oracle.proposed_predict(a, a, rows=rows)
    assert p.compression_ratio >= 1.0 - 1e-9
    assert 0 < p.nnz_total <= p.total_flop + 1e-9


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_value_scaling_invariance(seed):
    """The structure prediction depends only on sparsity, not values."""
    a = sprand.erdos_renyi(200, 200, 5, seed)
    b = sprand.erdos_renyi(200, 200, 5, seed + 1)
    a2 = type(a)(rpt=a.rpt, col=a.col, val=a.val * 7.5, shape=a.shape)
    rows = oracle.sample_rows(200, seed)
    p1 = oracle.proposed_predict(a, b, rows=rows)
    p2 = oracle.proposed_predict(a2, b, rows=rows)
    assert p1.nnz_total == p2.nnz_total


@given(st.integers(0, 500), st.integers(1, 50))
@settings(max_examples=15, deadline=None)
def test_sampled_counts_monotone_in_rows(seed, extra):
    """Adding sampled rows can only grow z* and f*."""
    a = sprand.power_law(300, 300, 6, 1.5, seed)
    rows1 = oracle.sample_rows(300, seed)[:5]
    rng = np.random.default_rng(seed + 1)
    rows2 = np.concatenate([rows1, rng.integers(0, 300, extra)])
    z1 = oracle.exact_sampled_nnz(a, a, rows1)
    z2 = oracle.exact_sampled_nnz(a, a, rows2)
    assert z2 >= z1


@given(st.integers(1, 5000))
@settings(max_examples=30, deadline=None)
def test_next_pow2_property(n):
    p = next_pow2(n)
    assert p >= n and p & (p - 1) == 0 and p < 2 * n + 2


@given(st.lists(st.integers(0, 100), min_size=1, max_size=64))
@settings(max_examples=25, deadline=None)
def test_bitonic_arbitrary_content(xs):
    from repro.kernels.sortnet import bitonic_sort
    import numpy as np
    n = next_pow2(len(xs))
    arr = np.full((1, n), np.iinfo(np.int32).max, np.int32)
    arr[0, :len(xs)] = xs
    out = np.asarray(bitonic_sort(jnp.asarray(arr)))[0]
    assert list(out[:len(xs)]) == sorted(xs)
