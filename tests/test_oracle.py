"""Numpy oracles vs brute-force dense math + the paper's error identities."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal CI image — deterministic tests must still run
    from hypothesis_shim import given, settings, st

from repro.sparse.formats import CSR
from repro.sparse import random as sprand
from repro.core import oracle


def _rand_pair(seed, m=60, k=50, n=40, da=4, db=5):
    a = sprand.erdos_renyi(m, k, da, seed)
    b = sprand.erdos_renyi(k, n, db, seed + 1)
    return a, b


def test_flop_per_row_bruteforce():
    a, b = _rand_pair(0)
    flopr, total = oracle.flop_per_row(a, b)
    ad, bd = a.to_dense() != 0, b.to_dense() != 0
    expect = (ad.astype(np.int64) @ bd.sum(1).astype(np.int64))
    np.testing.assert_array_equal(flopr, expect)
    assert total == expect.sum()


def test_exact_structure_bruteforce():
    a, b = _rand_pair(7)
    nnzr, z = oracle.exact_structure(a, b)
    cd = (a.to_dense() != 0).astype(np.int32) @ (b.to_dense() != 0).astype(np.int32)
    np.testing.assert_array_equal(nnzr, (cd > 0).sum(1))
    assert z == int((cd > 0).sum())


def test_exact_structure_chunking_invariant():
    a, b = _rand_pair(3, m=200)
    n1, z1 = oracle.exact_structure(a, b, chunk_flop=1 << 30)
    n2, z2 = oracle.exact_structure(a, b, chunk_flop=64)
    np.testing.assert_array_equal(n1, n2)
    assert z1 == z2


def test_spgemm_numeric_oracle():
    a, b = _rand_pair(11)
    c = oracle.spgemm(a, b)
    np.testing.assert_allclose(c.to_dense(), a.to_dense() @ b.to_dense(),
                               rtol=1e-5, atol=1e-5)


def test_full_sample_is_exact():
    """Sampling ALL rows makes both predictors exact (error → 0)."""
    a, b = _rand_pair(23)
    rows = np.arange(a.nrows)
    _, z = oracle.exact_structure(a, b)
    pp = oracle.proposed_predict(a, b, rows=rows)
    rp = oracle.reference_predict(a, b, rows=rows)
    assert abs(pp.nnz_total - z) / z < 1e-9
    assert abs(rp.nnz_total - z) / z < 1e-9


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_eq5_identity(seed):
    """Paper eq. 5: e2 == (e1 - ef) / (1 + ef), exactly, per construction."""
    a, b = _rand_pair(seed % 97, m=120)
    _, z = oracle.exact_structure(a, b)
    floprc, f_total = oracle.flop_per_row(a, b)
    rows = oracle.sample_rows(a.nrows, seed)
    pp = oracle.proposed_predict(a, b, rows=rows)
    rp = oracle.reference_predict(a, b, rows=rows)
    e1 = (rp.nnz_total - z) / z
    ef = (rp.sampled_flop / (rows.size / a.nrows) - f_total) / f_total
    e2 = (pp.nnz_total - z) / z
    assert abs(e2 - (e1 - ef) / (1 + ef)) < 1e-9


def test_structure_prediction_scales_with_flopr():
    """Predicted structure = floprC / CR* (the paper's final step)."""
    a, b = _rand_pair(5)
    floprc, _ = oracle.flop_per_row(a, b)
    pp = oracle.proposed_predict(a, b, seed=1)
    np.testing.assert_allclose(pp.structure, floprc / pp.compression_ratio)


def test_upper_bound_dominates_exact():
    a, b = _rand_pair(9)
    nnzr, _ = oracle.exact_structure(a, b)
    ub = oracle.upper_bound_predict(a, b)
    assert np.all(ub.structure >= nnzr)


def test_minhash_reasonable():
    """k-min-hash is a real estimator: within 50% on an easy case."""
    a = sprand.erdos_renyi(5000, 5000, 6, seed=42)
    _, z = oracle.exact_structure(a, a)
    mh = oracle.minhash_predict(a, a, seed=0, k=64)
    assert 0.5 * z < mh.nnz_total < 1.5 * z


def test_sample_rows_paper_rule():
    assert oracle.sample_rows(200_000, 0).size == 300      # cap
    assert oracle.sample_rows(50_000, 0).size == 150        # 0.003·M
    assert oracle.sample_rows(100, 0).size == 1             # floor → min 1


def test_stratified_predict_differentiates_mixed_cr():
    """Beyond-paper: per-segment CR captures heterogeneous compression that
    the global-CR prediction (∝ flopr) cannot."""
    from repro.sparse.formats import CSR
    m = 2000
    top = sprand.banded(m // 2, m, 40, 24, seed=1)      # high-CR rows
    bot = sprand.erdos_renyi(m // 2, m, 5, seed=2)      # CR≈1 rows
    rows = np.concatenate([np.repeat(np.arange(m // 2), top.row_nnz),
                           np.repeat(np.arange(m // 2, m), bot.row_nnz)])
    a = CSR.from_coo(rows, np.concatenate([top.col, bot.col]),
                     np.concatenate([top.val, bot.val]), (m, m), dedup=False)
    nnzr, z = oracle.exact_structure(a, a)
    sp = oracle.stratified_predict(a, a, seed=0, num_segments=16,
                                   per_segment=8)
    gp = oracle.proposed_predict(a, a, seed=0)
    # both totals accurate...
    assert abs(sp.nnz_total - z) / z < 0.15
    # ...but only the stratified structure tracks the per-half profile
    top_true = nnzr[: m // 2].mean() / max(nnzr[m // 2:].mean(), 1)
    top_strat = sp.structure[: m // 2].mean() / max(
        sp.structure[m // 2:].mean(), 1e-9)
    top_glob = gp.structure[: m // 2].mean() / max(
        gp.structure[m // 2:].mean(), 1e-9)
    assert abs(np.log(top_strat / top_true)) < abs(np.log(top_glob / top_true))
