"""Column-partitioned B (``plan_spgemm(n_panels=...)``, DESIGN.md §8).

Covers the §8 contracts host-side: panel-edge quantization properties
(pow2-grid edges collide iff band-equal), per-panel degree/FLOP tables,
per-(bucket, shard, panel) capacities, single-device (bucket × panel)
execution bitwise-equal to ``spgemm_binned``, the (bucket × panel) retry
unit under adversarial ``safety=0`` under-allocation, and the automatic
template registry.  The 4-device panel-gathered distributed path runs in a
subprocess (device-count env must precede jax init), like
``tests/test_distributed.py``.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal CI image — deterministic tests must still run
    from hypothesis_shim import given, settings, st

from repro.sparse import random as sprand
from repro.sparse.formats import CSR, spgemm_dense_oracle
from repro.core import binning, oracle, partition, plan as plan_mod
from repro.core import predictor, spgemm

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _families():
    return [
        ("er", sprand.erdos_renyi(400, 400, 4, seed=25),
         sprand.erdos_renyi(400, 400, 3, seed=26)),
        ("pl", sprand.power_law(500, 500, 5, 1.5, seed=21),
         sprand.power_law(500, 500, 4, 1.6, seed=22)),
        ("rmat", sprand.rmat(400, 400, 2000, seed=31),
         sprand.rmat(400, 400, 1600, seed=32)),
        ("band", sprand.banded(400, 400, 10, 14, seed=23),
         sprand.banded(400, 400, 8, 12, seed=24)),
        ("fem", sprand.banded(300, 300, 40, 30, seed=51),
         sprand.banded(300, 300, 32, 28, seed=52)),
    ]


def _revalue(m: CSR, seed: int) -> CSR:
    rng = np.random.default_rng(seed)
    return CSR(rpt=m.rpt.copy(), col=m.col.copy(),
               val=rng.standard_normal(m.nnz).astype(np.float32),
               shape=m.shape)


# --------------------------------------------------------------------------- #
# panel-edge quantization: the §8 half of the pow2 key contract
# --------------------------------------------------------------------------- #
@given(st.integers(64, 1 << 14), st.integers(2, 8),
       st.integers(0, 1 << 14), st.integers(0, 1 << 14))
@settings(max_examples=60, deadline=None)
def test_quantized_edges_collide_iff_same_band(ncols, n_panels, e1, e2):
    """Two interior edges land on the same quantized value exactly when they
    round to the same pow2-grid point — the hit-rate AND no-false-sharing
    guarantee of the panel key (mirrors the population pow2 property)."""
    g = partition.panel_grid(ncols, n_panels)
    e1, e2 = min(e1, max(0, ncols - g)), min(e2, max(0, ncols - g))
    q1 = partition.quantize_panel_edges(
        np.array([0] + [e1] * (n_panels - 1) + [ncols]), ncols)
    q2 = partition.quantize_panel_edges(
        np.array([0] + [e2] * (n_panels - 1) + [ncols]), ncols)
    same_band = (e1 + g // 2) // g == (e2 + g // 2) // g
    assert (q1[1] == q2[1]) == same_band
    # quantization distance bounded by half a grid step (unclipped regime)
    assert abs(int(q1[1]) - e1) <= g // 2
    assert int(q1[1]) % g == 0


@given(st.integers(64, 1 << 14), st.lists(st.integers(0, 1 << 14),
                                          min_size=1, max_size=7))
@settings(max_examples=40, deadline=None)
def test_quantized_edges_preserve_monotonicity_and_endpoints(ncols, inner):
    edges = np.concatenate([[0], np.sort(np.clip(inner, 0, ncols)), [ncols]])
    q = partition.quantize_panel_edges(edges, ncols)
    assert q[0] == 0 and q[-1] == ncols
    assert (np.diff(q) >= 0).all()
    assert (q >= 0).all() and (q <= ncols).all()


def test_column_panels_balance_and_cover():
    b = sprand.erdos_renyi(500, 500, 4, seed=3)
    for quantize in (False, True):
        pp = partition.column_panels(b, 4, quantize=quantize)
        assert pp.n_panels == 4
        assert pp.edges[0] == 0 and pp.edges[-1] == b.ncols
        assert (np.diff(pp.edges) >= 0).all()
        assert int(pp.panel_nnz.sum()) == b.nnz
        # ~equal B nnz per panel (quantized edges move ≤ half a grid step)
        assert pp.panel_nnz.max() <= 2 * max(1.0, b.nnz / 4)
        # panel_of is the inverse of the edge list
        pid = pp.panel_of(b.col)
        for p in range(4):
            sel = b.col[pid == p]
            if sel.size:
                assert sel.min() >= pp.edges[p]
                assert sel.max() < pp.edges[p + 1]


def test_quantized_panel_edges_stable_across_seeds():
    """Same-family different-seed B matrices land on the SAME panel key —
    the cache-stability motivation for quantized edges."""
    keys = set()
    for seed in (5, 7, 11):
        b = sprand.banded(600, 600, 12, 16, seed=seed)
        keys.add(partition.column_panels(b, 4, quantize=True).key)
    assert len(keys) == 1


# --------------------------------------------------------------------------- #
# per-panel degree/FLOP tables + capacities (the symbolic phase of §8)
# --------------------------------------------------------------------------- #
def test_panel_row_tables_partition_flop_exactly():
    a = sprand.power_law(300, 300, 5, 1.5, seed=1)
    b = sprand.power_law(300, 300, 4, 1.6, seed=2)
    pp = partition.column_panels(b, 3)
    pslices = plan_mod._slice_panels(b, pp.edges)
    dbmax_p, flopr_p = binning.panel_row_tables(
        a.rpt, a.col, [ps[0] for ps in pslices])
    flopr, _ = oracle.flop_per_row(a, b)
    # panels partition B's entries: per-panel FLOP sums to the full FLOP
    np.testing.assert_array_equal(flopr_p.sum(axis=0), flopr)
    # panel degree bounds never exceed the full-row bounds
    _, dbmax, _ = binning.row_widths(a.rpt, a.col, np.diff(b.rpt))
    assert (dbmax_p.max(axis=0) <= dbmax).all()


def test_shard_bucket_capacities_per_panel():
    a = sprand.power_law(400, 400, 5, 1.5, seed=9)
    p = plan_mod.plan_spgemm(a, a, safety=1.3)
    pp = partition.column_panels(a, 3)
    pslices = plan_mod._slice_panels(a, pp.edges)
    _, flopr_p = binning.panel_row_tables(a.rpt, a.col,
                                          [ps[0] for ps in pslices])
    structure_p = flopr_p / max(float(p.compression_ratio), 1e-9)
    bounds = np.array([0, 100, 250, 400])
    caps3, static3 = predictor.shard_bucket_capacities(
        p.binning, p.structure, p.flopr, bounds, safety=1.3,
        panel_structure=structure_p, panel_flopr=flopr_p)
    caps2, static2 = predictor.shard_bucket_capacities(
        p.binning, p.structure, p.flopr, bounds, safety=1.3)
    assert caps3.shape == (len(p.binning.buckets), 3, 3)
    for i in range(len(p.binning.buckets)):
        assert static3[i] == max(8, int(caps3[i].max()))
        # a row's panel output ⊆ its full output → panel statics never wider
        assert static3[i] <= static2[i]


# --------------------------------------------------------------------------- #
# single-device (bucket × panel) execution: bitwise parity with spgemm_binned
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name,a,b", _families(),
                         ids=[f[0] for f in _families()])
def test_panel_execution_bitwise_equal_to_spgemm_binned(name, a, b):
    p = plan_mod.plan_spgemm(a, b, safety=2.0, n_panels=3)
    out = plan_mod.execute(p, a, b)
    assert int(out.overflow) == 0
    c = plan_mod.reassemble(p, out)
    pl = plan_mod.plan_spgemm(a, b, safety=2.0, sample_rows=p.sample_rows)
    ob = spgemm.spgemm_binned(pl.to_device(a, "a"), pl.to_device(b, "b"),
                              pl.binning, alloc=pl.alloc)
    cl = plan_mod.reassemble(pl, ob)
    np.testing.assert_array_equal(c.rpt, cl.rpt)
    np.testing.assert_array_equal(c.col, cl.col)
    # panels preserve the per-column accumulation order (stable sort over
    # the same product subsequence), so ESC values match bitwise; SPA
    # buckets accumulate in dense-column order on both sides
    np.testing.assert_allclose(c.val, cl.val, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(c.to_dense(), spgemm_dense_oracle(a, b),
                               rtol=1e-4, atol=1e-4)


@given(st.integers(0, 10_000), st.integers(2, 5))
@settings(max_examples=6, deadline=None)
def test_panel_execution_property_random_family(seed, n_panels):
    """Hypothesis sweep: random family/seed/panel count — panel-partitioned
    execution equals ``spgemm_binned`` bitwise on rpt/col (the §8 panel
    half of the quantization-property contract)."""
    rng = np.random.default_rng(seed)
    m = int(rng.integers(60, 220))
    fam = seed % 3
    if fam == 0:
        a = sprand.erdos_renyi(m, m, int(rng.integers(2, 6)), seed=seed)
        b = sprand.erdos_renyi(m, m, int(rng.integers(2, 6)), seed=seed + 1)
    elif fam == 1:
        a = sprand.power_law(m, m, 4, 1.5, seed=seed)
        b = sprand.power_law(m, m, 3, 1.6, seed=seed + 1)
    else:
        a = sprand.banded(m, m, int(rng.integers(4, 10)), 8, seed=seed)
        b = sprand.banded(m, m, int(rng.integers(4, 10)), 6, seed=seed + 1)
    p = plan_mod.plan_spgemm(a, b, safety=2.0, n_panels=n_panels,
                             pop_quant=bool(seed % 2))
    c = plan_mod.reassemble(p, plan_mod.execute(p, a, b),
                            on_overflow="ignore")
    pl = plan_mod.plan_spgemm(a, b, safety=2.0, sample_rows=p.sample_rows)
    cl = plan_mod.reassemble(pl, plan_mod.execute(pl, a, b),
                             on_overflow="ignore")
    np.testing.assert_array_equal(c.rpt, cl.rpt)
    np.testing.assert_array_equal(c.col, cl.col)
    np.testing.assert_allclose(c.val, cl.val, rtol=1e-6, atol=1e-6)


def test_panel_serving_pair_shares_executor_zero_retraces():
    """Serving contract in panel mode: same structure, new values → same
    plan key, cached executor, ZERO retraces (the §6 pin extended to §8)."""
    a = sprand.banded(300, 300, 8, 12, seed=31)
    b = sprand.banded(300, 300, 6, 10, seed=32)
    cache = plan_mod.PlanCache()
    p1 = plan_mod.plan_spgemm(a, b, safety=2.0, n_panels=2)
    plan_mod.execute(p1, a, b, cache=cache)
    t0 = cache.stats()["traces"]
    a2, b2 = _revalue(a, 41), _revalue(b, 42)
    p2 = plan_mod.plan_spgemm(a2, b2, safety=2.0, n_panels=2)
    assert p2.key == p1.key
    out2 = plan_mod.execute(p2, a2, b2, cache=cache)
    assert cache.stats()["traces"] == t0, "panel serving pair retraced"
    c2 = plan_mod.reassemble(p2, out2)
    np.testing.assert_allclose(c2.to_dense(), spgemm_dense_oracle(a2, b2),
                               rtol=1e-4, atol=1e-4)


def test_panel_operand_validation():
    a = sprand.banded(200, 200, 6, 8, seed=3)
    p = plan_mod.plan_spgemm(a, a, safety=2.0, n_panels=2)
    with pytest.raises(plan_mod.PlanMismatchError, match="host CSR"):
        plan_mod.execute(p, a, p.to_device(a, "b"))
    other = sprand.banded(200, 200, 7, 9, seed=4)
    with pytest.raises(ValueError, match="re-plan"):
        plan_mod.execute(p, a, other)
    with pytest.raises(ValueError, match="divide"):
        plan_mod.plan_spgemm(a, a, num_shards=4, n_panels=3)


# --------------------------------------------------------------------------- #
# (bucket × panel) retry unit — adversarial safety=0 under-allocation
# --------------------------------------------------------------------------- #
def _panel_true_nnz(a: CSR, b: CSR, edges: np.ndarray) -> np.ndarray:
    """(n_panels, nrows) true structural nnz per output row per panel."""
    prod = (a.to_dense() != 0).astype(np.int64) @ \
        (b.to_dense() != 0).astype(np.int64)
    out = np.zeros((edges.size - 1, a.nrows), dtype=np.int64)
    for p in range(edges.size - 1):
        out[p] = (prod[:, edges[p]:edges[p + 1]] > 0).sum(axis=1)
    return out


@pytest.mark.parametrize("name,a,b", _families()[:3],
                         ids=[f[0] for f in _families()[:3]])
def test_panel_retry_re_executes_only_offending_units(name, a, b):
    cache = plan_mod.PlanCache()
    p = plan_mod.plan_spgemm(a, b, safety=0.0, retry_safety=1.5, n_panels=3)
    caps_before = np.asarray(p.panel_caps).copy()
    out = plan_mod.execute(p, a, b, cache=cache)

    true_p = _panel_true_nnz(a, b, p.panels.edges)
    expected = {
        (i, pa) for i, bk in enumerate(p.binning.buckets) if bk.n_rows
        for pa in range(p.n_panels)
        if int(true_p[pa, bk.rows].max()) > caps_before[i, pa]}
    assert expected, f"{name}: safety=0 failed to force under-allocation"

    assert p.retries >= 1
    assert int(out.overflow) == 0
    # the retry unit is (bucket × panel): exactly the offending units ran
    assert {(e["bucket"], e["panel"]) for e in p.retry_events} == expected
    for e in p.retry_events:
        assert e["new_cap"] >= e["need"] > e["old_cap"]

    # bitwise contract vs an ample binned run on the same sample
    pa_plan = plan_mod.plan_spgemm(a, b, safety=64.0,
                                   sample_rows=p.sample_rows)
    oa = spgemm.spgemm_binned(pa_plan.to_device(a, "a"),
                              pa_plan.to_device(b, "b"),
                              pa_plan.binning, alloc=pa_plan.alloc)
    assert int(oa.overflow) == 0
    ca = plan_mod.reassemble(pa_plan, oa)
    c = plan_mod.reassemble(p, out)
    np.testing.assert_array_equal(c.rpt, ca.rpt)
    np.testing.assert_array_equal(c.col, ca.col)
    np.testing.assert_allclose(c.val, ca.val, rtol=1e-5, atol=1e-5)

    # capacities were bumped in place: the same plan allocates right now
    out2 = plan_mod.execute(p, a, b, cache=cache)
    assert p.retries == 0 and int(out2.overflow) == 0


# --------------------------------------------------------------------------- #
# automatic template selection (TemplateRegistry)
# --------------------------------------------------------------------------- #
def test_auto_template_registry_steady_state_reuse():
    """``template="auto"``: same-family different-seed members resolve to
    one registry template and, after warmup, land on ONE plan key with zero
    retraces — no caller-held handle."""
    reg = plan_mod.TemplateRegistry()
    cache = plan_mod.PlanCache()
    gen = lambda s: (sprand.erdos_renyi(400, 400, 4, seed=s),
                     sprand.erdos_renyi(400, 400, 3, seed=s + 50))
    members = [gen(i) for i in range(4)]
    for a, b in members:                     # warmup: template may grow
        plan_mod.execute(plan_mod.plan_spgemm(a, b, safety=1.3,
                                              template="auto", registry=reg),
                         a, b, cache=cache)
    assert reg.stats()["misses"] == 1        # one sketch → one template
    assert reg.stats()["hits"] == len(members) - 1
    t0 = cache.stats()["traces"]
    keys = set()
    for a, b in members:
        p = plan_mod.plan_spgemm(a, b, safety=1.3, template="auto",
                                 registry=reg)
        plan_mod.execute(p, a, b, cache=cache)
        keys.add(p.key)
    assert len(keys) == 1, "steady-state members landed on different keys"
    assert cache.stats()["traces"] == t0, "steady-state member retraced"


def test_structural_sketch_separates_shapes_and_regimes():
    a1 = sprand.erdos_renyi(300, 300, 4, seed=1)
    a2 = sprand.erdos_renyi(300, 300, 4, seed=2)
    big = sprand.erdos_renyi(400, 400, 4, seed=1)
    dense = sprand.erdos_renyi(300, 300, 24, seed=1)
    reg = plan_mod.TemplateRegistry()
    sentinel = object()
    reg.get_or_create(a1, a1, lambda: sentinel)
    assert reg.lookup(a2, a2) is sentinel    # same family resolves (tolerant)
    assert reg.lookup(big, big) is None      # shape separates (exact)
    assert reg.lookup(dense, dense) is None  # degree regime separates


def test_auto_template_rejects_unknown_mode():
    a = sprand.banded(100, 100, 4, 6, seed=1)
    with pytest.raises(ValueError, match="template mode"):
        plan_mod.plan_spgemm(a, a, template="bogus")


# --------------------------------------------------------------------------- #
# 4-device panel-gathered distributed path (subprocess, like
# tests/test_distributed.py): the ISSUE 5 acceptance suite
# --------------------------------------------------------------------------- #
PANEL_DIST_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax

from repro.sparse import random as sprand
from repro.sparse.formats import CSR, spgemm_dense_oracle
from repro.core import plan as plan_mod, spgemm

def revalue(m, seed):
    rng = np.random.default_rng(seed)
    return CSR(rpt=m.rpt.copy(), col=m.col.copy(),
               val=rng.standard_normal(m.nnz).astype(np.float32),
               shape=m.shape)

mesh = jax.make_mesh((4,), ("data",))
fams = [
    ("er", sprand.erdos_renyi(400, 400, 4, seed=25),
     sprand.erdos_renyi(400, 400, 3, seed=26)),
    ("pl", sprand.power_law(500, 500, 5, 1.5, seed=21),
     sprand.power_law(500, 500, 4, 1.6, seed=22)),
    ("rmat", sprand.rmat(400, 400, 2000, seed=31),
     sprand.rmat(400, 400, 1600, seed=32)),
    ("band", sprand.banded(400, 400, 10, 14, seed=23),
     sprand.banded(400, 400, 8, 12, seed=24)),
    ("fem", sprand.banded(300, 300, 40, 30, seed=51),
     sprand.banded(300, 300, 32, 28, seed=52)),
]
out = {}
for fam, a, b in fams:
    rec = {}
    for P in (2, 4):
        use_kernel = fam == "band" and P == 2   # kernel route on gathered B
        cache = plan_mod.PlanCache()
        p = plan_mod.plan_spgemm(a, b, mesh=mesh, safety=2.0, n_panels=P,
                                 use_kernel=use_kernel)
        res = plan_mod.execute(p, a, b, cache=cache)
        c = plan_mod.reassemble(p, res)
        pl = plan_mod.plan_spgemm(a, b, safety=2.0,
                                  sample_rows=p.sample_rows)
        ob = spgemm.spgemm_binned(pl.to_device(a, "a"), pl.to_device(b, "b"),
                                  pl.binning, alloc=pl.alloc)
        cl = plan_mod.reassemble(pl, ob)
        # serving: same structure, new values → cached executor, 0 retraces
        t0 = cache.stats()["traces"]
        a2, b2 = revalue(a, 91), revalue(b, 92)
        p2 = plan_mod.plan_spgemm(a2, b2, mesh=mesh, safety=2.0, n_panels=P,
                                  use_kernel=use_kernel)
        res2 = plan_mod.execute(p2, a2, b2, cache=cache)
        c2 = plan_mod.reassemble(p2, res2)
        rec[str(P)] = dict(
            overflow=int(res.shard_overflow.sum()),
            rpt_eq=bool((c.rpt == cl.rpt).all()),
            col_eq=bool((c.col == cl.col).all()),
            vdiff=float(np.abs(c.val - cl.val).max()),
            ref_err=float(np.abs(c.to_dense()
                                 - spgemm_dense_oracle(a, b)).max()),
            same_key=bool(p2.key == p.key),
            retraces=cache.stats()["traces"] - t0,
            err2=float(np.abs(c2.to_dense()
                              - spgemm_dense_oracle(a2, b2)).max()),
            comm=p.comm_stats(),
        )
    out[fam] = rec

# (bucket × panel) retry under adversarial under-allocation, 2×2 fold
fam, a, b = fams[1]
cache = plan_mod.PlanCache()
p = plan_mod.plan_spgemm(a, b, mesh=mesh, safety=0.0, retry_safety=1.5,
                         n_panels=2)
caps_before = np.asarray(p.panel_caps).copy()
res = plan_mod.execute(p, a, b, cache=cache)
c = plan_mod.reassemble(p, res)
prod = (a.to_dense() != 0).astype(np.int64) @ (b.to_dense() != 0).astype(np.int64)
edges = p.panels.edges
expected = set()
for i, bk in enumerate(p.binning.buckets):
    if not bk.n_rows:
        continue
    for pa in range(p.n_panels):
        tp = (prod[bk.rows, edges[pa]:edges[pa + 1]] > 0).sum(axis=1)
        if int(tp.max()) > caps_before[i, pa]:
            expected.add((i, pa))
pl = plan_mod.plan_spgemm(a, b, safety=64.0, sample_rows=p.sample_rows)
ob = spgemm.spgemm_binned(pl.to_device(a, "a"), pl.to_device(b, "b"),
                          pl.binning, alloc=pl.alloc)
cl = plan_mod.reassemble(pl, ob)
first_retries = int(p.retries)
retried = sorted([list(u) for u in
                  {(e["bucket"], e["panel"]) for e in p.retry_events}])
res_again = plan_mod.execute(p, a, b, cache=cache)
out["retry"] = dict(
    retries=first_retries,
    overflow=int(res.shard_overflow.sum()),
    retried=retried,
    expected=sorted([list(u) for u in expected]),
    rpt_eq=bool((c.rpt == cl.rpt).all()),
    col_eq=bool((c.col == cl.col).all()),
    vdiff=float(np.abs(c.val - cl.val).max()),
    overflow2=int(res_again.shard_overflow.sum()),
    retries2=int(p.retries),
)
print(json.dumps(out))
"""


def _run(script: str, timeout: int = 1800) -> dict:
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_panel_distributed_4dev_all_families():
    rec = _run(PANEL_DIST_SCRIPT)
    for fam in ("er", "pl", "rmat", "band", "fem"):
        for P in ("2", "4"):
            r = rec[fam][P]
            assert r["overflow"] == 0, (fam, P, r)
            assert r["rpt_eq"] and r["col_eq"], (fam, P, r)
            assert r["vdiff"] < 1e-4, (fam, P, r)
            assert r["ref_err"] < 1e-3, (fam, P, r)
            # zero-retrace serving through the panel executors
            assert r["same_key"], (fam, P, r)
            assert r["retraces"] == 0, (fam, P, r)
            assert r["err2"] < 1e-3, (fam, P, r)
            # B never replicates: per-device footprint strictly below the
            # replicated operand, payload scaling with the panel count
            assert r["comm"]["per_device_b_bytes"] \
                < r["comm"]["replicated_b_bytes"], (fam, P, r)
    # the pl family at 4 panels shows the ~n_panels× payload reduction
    assert rec["pl"]["4"]["comm"]["payload_reduction"] >= 0.75 * 4, rec["pl"]
    # retry: only the offending (bucket × panel) units re-executed,
    # converged, bitwise vs the ample reference
    r = rec["retry"]
    assert r["retries"] >= 1, r
    assert r["overflow"] == 0, r
    assert r["retried"] == r["expected"], r
    assert r["rpt_eq"] and r["col_eq"], r
    assert r["vdiff"] < 1e-4, r
    # bumped-in-place capacities: the second execute needs no retry rounds
    assert r["overflow2"] == 0 and r["retries2"] == 0, r
