"""Unified plan/execute pipeline (core/plan.py): local parity, cache contract,
per-shard capacity sizing, and degenerate inputs.

The distributed executor itself runs under a 4-device mesh in
``tests/test_distributed.py`` (subprocess — device count must precede jax
init); everything here is single-device."""
import jax
import numpy as np
import pytest

from repro.sparse import random as sprand
from repro.sparse.formats import CSR, spgemm_dense_oracle
from repro.core import binning, csr, oracle, plan as plan_mod
from repro.core import predictor, spgemm


def _revalue(m: CSR, seed: int) -> CSR:
    """Same sparsity structure, fresh values — the serving scenario."""
    rng = np.random.default_rng(seed)
    return CSR(rpt=m.rpt.copy(), col=m.col.copy(),
               val=rng.standard_normal(m.nnz).astype(np.float32),
               shape=m.shape)


def _hub_matrix(m=400, hub_deg=200):
    rng = np.random.default_rng(0)
    rows = np.repeat(np.arange(1, m), 2)
    cols = rng.integers(0, m, rows.size)
    hub_cols = rng.choice(m, hub_deg, replace=False)
    rows = np.concatenate([np.zeros(hub_deg, np.int64), rows])
    cols = np.concatenate([hub_cols, cols])
    vals = rng.standard_normal(rows.size).astype(np.float32)
    return CSR.from_coo(rows, cols, vals, (m, m))


# --------------------------------------------------------------------------- #
# local execute == spgemm_binned (bitwise)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name,a,b", [
    ("pl", sprand.power_law(500, 500, 5, 1.5, seed=21),
     sprand.power_law(500, 500, 4, 1.6, seed=22)),
    ("band", sprand.banded(400, 400, 10, 14, seed=23),
     sprand.banded(400, 400, 8, 12, seed=24)),
    ("er", sprand.erdos_renyi(400, 400, 4, seed=25),
     sprand.erdos_renyi(400, 400, 3, seed=26)),
], ids=["pl", "band", "er"])
def test_execute_local_matches_spgemm_binned(name, a, b):
    p = plan_mod.plan_spgemm(a, b, safety=2.0)
    out = plan_mod.execute(p, a, b)
    ob = spgemm.spgemm_binned(p.to_device(a, "a"), p.to_device(b, "b"),
                              p.binning, alloc=p.alloc)
    np.testing.assert_array_equal(np.asarray(out.col), np.asarray(ob.col))
    np.testing.assert_array_equal(np.asarray(out.val), np.asarray(ob.val))
    np.testing.assert_array_equal(np.asarray(out.row_nnz),
                                  np.asarray(ob.row_nnz))
    assert int(out.overflow) == int(ob.overflow)
    c = plan_mod.reassemble(p, out)
    np.testing.assert_allclose(c.to_dense(), spgemm_dense_oracle(a, b),
                               rtol=1e-4, atol=1e-4)


def test_execute_accepts_device_operands_and_checks_shapes():
    a = sprand.banded(200, 200, 6, 8, seed=3)
    p = plan_mod.plan_spgemm(a, a, safety=2.0)
    ad = p.to_device(a, "a")
    out = plan_mod.execute(p, ad, ad)
    assert int(out.overflow) == 0
    with pytest.raises(ValueError):
        p.to_device(sprand.banded(100, 100, 6, 8, seed=3), "a")


# --------------------------------------------------------------------------- #
# plan cache: signature-keyed executables, zero retraces in serving
# --------------------------------------------------------------------------- #
def test_plan_cache_zero_retraces_on_same_signature_pair():
    cache = plan_mod.PlanCache()
    a1 = sprand.banded(400, 400, 8, 12, seed=31)
    b1 = sprand.banded(400, 400, 6, 10, seed=32)
    p1 = plan_mod.plan_spgemm(a1, b1, safety=2.0)
    out1 = plan_mod.execute(p1, a1, b1, cache=cache)
    first = cache.stats()
    assert first["misses"] == 1 and first["traces"] >= 1

    # same structure, new values: same plan key → cached executable, and
    # the compile-count pin — ZERO additional traces
    a2, b2 = _revalue(a1, 41), _revalue(b1, 42)
    p2 = plan_mod.plan_spgemm(a2, b2, safety=2.0)
    assert p2.key == p1.key
    out2 = plan_mod.execute(p2, a2, b2, cache=cache)
    second = cache.stats()
    assert second["hits"] == 1
    assert second["traces"] == first["traces"], "serving pair retraced"
    # and the cached executable computes the right thing
    c2 = plan_mod.reassemble(p2, out2)
    np.testing.assert_allclose(c2.to_dense(), spgemm_dense_oracle(a2, b2),
                               rtol=1e-4, atol=1e-4)
    # row_nnz is structure-determined: bitwise across the pair
    np.testing.assert_array_equal(np.asarray(out1.row_nnz),
                                  np.asarray(out2.row_nnz))


def test_plan_key_differs_on_shape_and_safety():
    a = sprand.banded(300, 300, 8, 12, seed=33)
    p1 = plan_mod.plan_spgemm(a, a, safety=1.05)
    p2 = plan_mod.plan_spgemm(a, a, safety=3.0)
    b = sprand.banded(320, 320, 8, 12, seed=33)
    p3 = plan_mod.plan_spgemm(b, b, safety=1.05)
    assert p1.key != p3.key
    # different safety → different capacities → different executable key
    # (1.05 stays below the flopr ceiling, 3.0 saturates it)
    assert p1.alloc.bucket_capacities != p2.alloc.bucket_capacities
    assert p1.key != p2.key


def test_default_session_cache_is_used():
    a = sprand.erdos_renyi(150, 150, 3, seed=7)
    p = plan_mod.plan_spgemm(a, a, safety=2.0)
    before = plan_mod.plan_cache().stats()["misses"]
    plan_mod.execute(p, a, a)
    assert plan_mod.plan_cache().stats()["misses"] >= before


# --------------------------------------------------------------------------- #
# per-shard capacity sizing: the hub-row regression (satellite of ISSUE 3)
# --------------------------------------------------------------------------- #
def _legacy_global_pad_slots(a, num_shards=4, safety=1.3):
    """The retired global-pad sizing rule (``benchmarks/legacy_distributed``):
    every shard allocates rows_per_shard × ONE global row capacity sized by
    the worst predicted row in the whole matrix — inlined here so the
    regression pin survives the legacy path leaving the library."""
    flopr, _ = oracle.flop_per_row(a, a)
    pred = oracle.proposed_predict(a, a, seed=0)
    from repro.core import partition
    part = partition.balanced_contiguous(pred.structure, num_shards)
    rows_per_shard = int(max(np.diff(part.bounds).max(), 1))
    cap = int(min(np.ceil(pred.structure.max() * safety), flopr.max()))
    cap = max(8, -(-cap // 8) * 8)
    return rows_per_shard * cap


def test_hub_row_no_longer_inflates_other_shards_buffers():
    """The legacy global-pad path sized EVERY shard's buffers from the
    global max predicted row, so one hub row inflated all shards.  The
    unified plan isolates the hub in its own bucket: every other bucket's
    capacity is sized by its own rows, and the per-shard footprint drops by
    an order of magnitude."""
    a = _hub_matrix()
    legacy_slots = _legacy_global_pad_slots(a, num_shards=4)

    p = plan_mod.plan_spgemm(a, a, num_shards=4, safety=1.3)
    new_slots = p.shard_slots()
    assert new_slots * 5 < legacy_slots, (new_slots, legacy_slots)

    # the hub's capacity applies only to its own (tiny) bucket...
    hub_bucket = int(p.binning.row_bucket[0])
    caps = [t.capacity for t in p.shard_tables]
    assert caps[hub_bucket] == max(caps)
    assert p.binning.buckets[hub_bucket].n_rows < 50
    # ...and per-(bucket, shard) needs show shards WITHOUT the hub never
    # requiring the hub capacity for any other bucket
    hub_shard = int(np.searchsorted(p.partition.bounds, 0, side="right")) - 1
    other = np.delete(np.arange(4), hub_shard)
    non_hub = np.delete(np.arange(len(caps)), hub_bucket)
    if non_hub.size:
        assert p.shard_capacities[non_hub][:, other].max() < caps[hub_bucket]


def test_shard_tables_partition_rows_exactly():
    a = sprand.power_law(600, 600, 5, 1.5, seed=50)
    p = plan_mod.plan_spgemm(a, a, num_shards=4, safety=2.0)
    seen = []
    for t in p.shard_tables:
        for s in range(t.table.shape[0]):
            seen.append(t.table[s][t.valid[s]])
    seen = np.sort(np.concatenate(seen))
    np.testing.assert_array_equal(seen, np.arange(a.nrows))
    # every shard's valid rows fall inside its partition range
    for t in p.shard_tables:
        for s in range(4):
            ids = t.table[s][t.valid[s]]
            if ids.size:
                assert ids.min() >= p.partition.bounds[s]
                assert ids.max() < p.partition.bounds[s + 1]


# --------------------------------------------------------------------------- #
# degenerate inputs
# --------------------------------------------------------------------------- #
def test_empty_matrix_plans_and_reassembles():
    a = CSR(rpt=np.zeros(1, np.int64), col=np.zeros(0, np.int32),
            val=np.zeros(0, np.float32), shape=(0, 0))
    p = plan_mod.plan_spgemm(a, a)
    out = plan_mod.execute(p, a, a)
    c = plan_mod.reassemble(p, out)
    assert c.nnz == 0 and c.shape == (0, 0)


def test_all_zero_nnz_rows_reassemble_empty():
    """Every row empty → all shard outputs empty; reassemble must not crash
    (the legacy np.concatenate-of-empty-list bug, fixed alongside)."""
    a = CSR(rpt=np.zeros(6, np.int64), col=np.zeros(0, np.int32),
            val=np.zeros(0, np.float32), shape=(5, 5))
    p = plan_mod.plan_spgemm(a, a, min_rows=1)
    out = plan_mod.execute(p, a, a)
    c = plan_mod.reassemble(p, out)
    assert c.nnz == 0 and c.shape == (5, 5)


def test_reassemble_raises_on_overflow():
    a = sprand.banded(200, 200, 10, 12, seed=9)
    p = plan_mod.plan_spgemm(a, a, safety=2.0)
    # shrink every capacity to force dropped entries
    p.alloc = predictor.BinnedAllocationPlan(
        bucket_capacities=tuple(8 for _ in p.alloc.bucket_capacities),
        row_capacity=8, total_capacity=8 * a.nrows, safety=0.0)
    out = plan_mod.execute(p, a, a)
    assert int(out.overflow) > 0
    with pytest.raises(ValueError, match="overflow"):
        plan_mod.reassemble(p, out)
    with pytest.raises(ValueError, match="on_overflow"):
        plan_mod.reassemble(p, out, on_overflow="warn")   # typo-proof
    c = plan_mod.reassemble(p, out, on_overflow="ignore")
    assert c.nnz < int(np.asarray(out.row_nnz).sum())


def test_execute_rejects_mismatched_mesh():
    a = sprand.banded(200, 200, 6, 8, seed=13)
    p = plan_mod.plan_spgemm(a, a, num_shards=4, safety=2.0)
    mesh = jax.make_mesh((1,), ("data",))      # single-device test env
    with pytest.raises(ValueError, match="4 shards"):
        plan_mod.execute(p, a, a, mesh=mesh)
