"""Tiny fallback for ``hypothesis`` when it isn't installed.

Implements just the API surface these tests use — ``given``, ``settings``,
``strategies.integers`` / ``strategies.lists`` — as a deterministic example
sweep (bounds first, then seeded randoms).  Property tests keep running in
minimal CI images instead of ERRORing the whole collection; install the real
``hypothesis`` to get shrinking and the full search strategy.
"""
from __future__ import annotations

import random


class _Integers:
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = lo, hi
        self._calls = 0

    def _gen(self, rng: random.Random):
        self._calls += 1
        if self._calls == 1:
            return self.lo
        if self._calls == 2:
            return self.hi
        return rng.randint(self.lo, self.hi)


class _Lists:
    def __init__(self, elem, min_size: int, max_size: int):
        self.elem, self.min_size, self.max_size = elem, min_size, max_size

    def _gen(self, rng: random.Random):
        k = rng.randint(self.min_size, self.max_size)
        return [self.elem._gen(rng) for _ in range(k)]


class st:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Integers:
        return _Integers(min_value, max_value)

    @staticmethod
    def lists(elem, min_size: int = 0, max_size: int = 10) -> _Lists:
        return _Lists(elem, min_size, max_size)


def settings(**kwargs):
    def deco(fn):
        fn._shim_max_examples = kwargs.get("max_examples", 10)
        return fn
    return deco


def given(*strats):
    def deco(fn):
        def run(*args, **kwargs):
            n = getattr(run, "_shim_max_examples",
                        getattr(fn, "_shim_max_examples", 10))
            rng = random.Random(0)
            for _ in range(n):
                vals = [s._gen(rng) for s in strats]
                fn(*args, *vals, **kwargs)
        # NOT functools.wraps: pytest must see a parameterless signature,
        # otherwise the example arguments look like missing fixtures.
        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        return run
    return deco
