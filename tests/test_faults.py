"""Fault-injection containment suite (DESIGN.md §9).

The contract under test: for EVERY injected fault class on EVERY suite
family, ``plan_spgemm``/``execute``/``reassemble`` either produce a result
bitwise-equal to an ample-capacity reference (exact ``rpt``/``col``, values
to float tolerance) or raise the matching typed
:mod:`repro.core.errors` subclass — never a silently corrupted matrix.

Fault classes (see :mod:`repro.core.faults`):

* capacity starvation  — predictor under-shoots every bucket capacity
* sketch corruption    — the sampled structural sketch itself is wrong
* gather starvation    — panel-gather entry capacity below the payload
* executor failure     — an executor dies mid-dispatch
* malformed operand    — NaN smuggled into an operand's values

Plus the escalation-budget pins: the retry ladder terminates in at most
``rounds + 1`` executes per (bucket) unit, and an ARMED no-fault plan pays
zero extra retraces.  The 4-device shard_map variant runs in a subprocess
(device-count env must precede jax init), like ``tests/test_replan.py``.
"""
import json
import os
import subprocess
import sys
from collections import Counter

import numpy as np
import pytest

from repro.sparse import random as sprand
from repro.sparse.formats import CSR, spgemm_dense_oracle
from repro.core import faults, plan as plan_mod, spgemm
from repro.core.errors import (CapacityExhaustedError, OperandValidationError,
                               ShardFailureError, SpgemmError)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _families():
    return [
        ("er", sprand.erdos_renyi(250, 250, 4, seed=25),
         sprand.erdos_renyi(250, 250, 3, seed=26)),
        ("pl", sprand.power_law(300, 300, 5, 1.5, seed=21),
         sprand.power_law(300, 300, 4, 1.6, seed=22)),
        ("rmat", sprand.rmat(250, 250, 1250, seed=31),
         sprand.rmat(250, 250, 1000, seed=32)),
        ("band", sprand.banded(250, 250, 10, 14, seed=23),
         sprand.banded(250, 250, 8, 12, seed=24)),
        ("fem", sprand.banded(160, 160, 40, 30, seed=51),
         sprand.banded(160, 160, 32, 28, seed=52)),
    ]


def _reference(p, a, b):
    """Ample-capacity binned run on the same sample — the bitwise ground
    truth a fault-recovered result must match."""
    pa = plan_mod.plan_spgemm(a, b, safety=64.0, sample_rows=p.sample_rows)
    oa = spgemm.spgemm_binned(pa.to_device(a, "a"), pa.to_device(b, "b"),
                              pa.binning, alloc=pa.alloc)
    assert int(oa.overflow) == 0, "reference must not overflow"
    return plan_mod.reassemble(pa, oa)


def _assert_bitwise(c, ca, a, b):
    np.testing.assert_array_equal(c.rpt, ca.rpt)
    np.testing.assert_array_equal(c.col, ca.col)
    np.testing.assert_allclose(c.val, ca.val, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(c.to_dense(), spgemm_dense_oracle(a, b),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------- #
# containment matrix: 5 fault classes x 5 suite families
# --------------------------------------------------------------------------- #
# (name, inject kwargs, plan kwargs, outcome, expected error classes)
FAULTS = [
    ("capacity", dict(capacity_scale=0.2), {}, "recover", ()),
    ("sketch", dict(sketch_scale=0.05), {}, "recover", ()),
    ("gather", dict(gather_scale=0.25), dict(n_panels=2), "raise",
     (CapacityExhaustedError, ShardFailureError)),
    ("executor", dict(fail_executor={"unit": "local"}), {}, "raise",
     (ShardFailureError,)),
    ("operand", None, {}, "raise", (OperandValidationError,)),
]


@pytest.mark.parametrize("fault,inj,pkw,outcome,errs", FAULTS,
                         ids=[f[0] for f in FAULTS])
@pytest.mark.parametrize("name,a,b", _families(),
                         ids=[f[0] for f in _families()])
def test_containment_matrix(name, a, b, fault, inj, pkw, outcome, errs):
    if fault == "operand":
        bad = a.val.copy()
        bad[bad.size // 2] = np.nan
        a = CSR(a.rpt, a.col, bad, a.shape)
    policy = plan_mod.RetryPolicy(rounds=2)
    try:
        with faults.inject(**(inj or {})):
            p = plan_mod.plan_spgemm(a, b, safety=1.3, retry_policy=policy,
                                     **pkw)
            out = plan_mod.execute(p, a, b, cache=plan_mod.PlanCache())
            c = plan_mod.reassemble(p, out)
    except SpgemmError as e:
        assert outcome == "raise", f"{name}/{fault}: unexpected {e!r}"
        assert isinstance(e, errs), f"{name}/{fault}: wrong class {type(e)}"
        assert isinstance(e, ValueError)       # back-compat contract
        return
    assert outcome == "recover", f"{name}/{fault}: fault was not detected"
    assert not int(np.asarray(getattr(out, "overflow", 0)))
    _assert_bitwise(c, _reference(p, a, b), a, b)


# --------------------------------------------------------------------------- #
# escalation budget + typed-exhaustion pins
# --------------------------------------------------------------------------- #
def test_escalation_terminates_within_budget():
    """Under uniform starvation the escalation runs at most ``rounds``
    ladder executes plus one exact-fallback execute per bucket — and the
    result is still bitwise-correct."""
    _, a, b = _families()[1]       # power-law: widest bucket spread
    policy = plan_mod.RetryPolicy(rounds=2, growth=1.5)
    with faults.inject(capacity_scale=0.15):
        p = plan_mod.plan_spgemm(a, b, safety=1.3, retry_policy=policy)
        out = plan_mod.execute(p, a, b, cache=plan_mod.PlanCache())
    assert int(out.overflow) == 0
    assert p.retries <= policy.rounds
    ladder = Counter(e["bucket"] for e in p.retry_events)
    exact = Counter(d["bucket"] for d in p.degradations)
    for i in set(ladder) | set(exact):
        assert ladder[i] + exact[i] <= policy.rounds + 1, (i, ladder, exact)
        assert exact[i] <= 1, "exact fallback must execute at most once"
    # the degradation ledger is the observable record of the escalation
    st = p.stats()
    assert st["degradations"] == p.degradations
    json.dumps(st)                 # and it stays JSON-serializable
    _assert_bitwise(plan_mod.reassemble(p, out), _reference(p, a, b), a, b)


def test_exact_fallback_alone_closes_overflow():
    """rounds=0 + exact_fallback: no ladder rounds at all — the symbolic
    escape hatch must close every overflow in ONE extra execute per bucket."""
    _, a, b = _families()[3]
    policy = plan_mod.RetryPolicy(rounds=0, exact_fallback=True)
    with faults.inject(capacity_scale=0.2):
        p = plan_mod.plan_spgemm(a, b, safety=1.3, retry_policy=policy)
        out = plan_mod.execute(p, a, b, cache=plan_mod.PlanCache())
    assert p.retries == 0 and not p.retry_events
    assert p.degradations, "starved caps must show up as degradations"
    assert all(d["kind"] == "exact_symbolic" and d["new_cap"] >= d["need"]
               for d in p.degradations)
    assert int(out.overflow) == 0
    _assert_bitwise(plan_mod.reassemble(p, out), _reference(p, a, b), a, b)


def test_exhaustion_raises_typed_error():
    """No budget, no fallback, raise-on-exhausted: the failure is a
    CapacityExhaustedError naming the starved buckets — never silent."""
    _, a, b = _families()[0]
    policy = plan_mod.RetryPolicy(rounds=0, exact_fallback=False,
                                  on_exhausted="raise")
    with faults.inject(capacity_scale=0.1):
        p = plan_mod.plan_spgemm(a, b, safety=1.3, retry_policy=policy)
        with pytest.raises(CapacityExhaustedError) as exc:
            plan_mod.execute(p, a, b, cache=plan_mod.PlanCache())
    assert exc.value.context["buckets"], "error must name the starved buckets"
    assert exc.value.context["observed"] > 0


def test_executor_fault_wraps_cause():
    _, a, b = _families()[0]
    with faults.inject(fail_executor={"unit": "local"}):
        p = plan_mod.plan_spgemm(a, b, safety=1.3,
                                 retry_policy=plan_mod.RetryPolicy())
        with pytest.raises(ShardFailureError) as exc:
            plan_mod.execute(p, a, b, cache=plan_mod.PlanCache())
    assert exc.value.context["unit"] == "local"
    assert isinstance(exc.value.__cause__, faults.InjectedFault)


def test_gather_starvation_names_panel():
    _, a, b = _families()[3]
    with faults.inject(gather_scale=0.25):
        p = plan_mod.plan_spgemm(a, b, safety=1.3, n_panels=2)
        with pytest.raises(CapacityExhaustedError) as exc:
            plan_mod.execute(p, a, b, cache=plan_mod.PlanCache())
    ctx = exc.value.context
    assert "panel" in ctx and ctx["observed"] > ctx["planned"]


def test_no_fault_armed_path_zero_retraces():
    """Arming RetryPolicy costs nothing on the happy path: no retries, no
    degradations, and a second execute through the same cache retraces
    NOTHING (compile-count pinned)."""
    a = sprand.banded(300, 300, 8, 10, seed=3)
    cache = plan_mod.PlanCache()
    p = plan_mod.plan_spgemm(a, a, safety=2.0,
                             retry_policy=plan_mod.RetryPolicy())
    out = plan_mod.execute(p, a, a, cache=cache)
    assert p.retries == 0 and not p.retry_events and not p.degradations
    assert int(out.overflow) == 0
    t = cache.stats()["traces"]
    plan_mod.execute(p, a, a, cache=cache)
    assert cache.stats()["traces"] == t, "no-fault armed path retraced"
    st = p.stats()
    assert st["retries"] == 0 and st["degradations"] == []
    assert st["validation"]["operands_validated"] == 2


# --------------------------------------------------------------------------- #
# 4-device shard_map: distributed containment (subprocess, like
# tests/test_replan.py)
# --------------------------------------------------------------------------- #
FAULTS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax

from repro.sparse import random as sprand
from repro.sparse.formats import spgemm_dense_oracle
from repro.core import faults, plan as plan_mod, spgemm
from repro.core.errors import ShardFailureError

mesh = jax.make_mesh((4,), ("data",))
a = sprand.banded(400, 400, 10, 14, seed=23)
b = sprand.banded(400, 400, 8, 12, seed=24)
out = {}

# executor death on a shard dispatch -> ShardFailureError naming the unit
try:
    with faults.inject(fail_executor={"unit": "dist"}):
        p = plan_mod.plan_spgemm(a, b, mesh=mesh, safety=1.3,
                                 retry_policy=plan_mod.RetryPolicy())
        plan_mod.execute(p, a, b, cache=plan_mod.PlanCache())
    out["exec"] = dict(raised=False)
except ShardFailureError as e:
    out["exec"] = dict(raised=True, unit=e.context.get("unit"),
                       cause=type(e.__cause__).__name__)

# panel-gather starvation -> ShardFailureError at plan time, naming
# shard AND panel
try:
    with faults.inject(gather_scale=0.25):
        plan_mod.plan_spgemm(a, b, mesh=mesh, n_panels=2, safety=1.3)
    out["gather"] = dict(raised=False)
except ShardFailureError as e:
    out["gather"] = dict(raised=True,
                         has_shard="shard" in e.context,
                         has_panel="panel" in e.context,
                         starved=e.context.get("observed", 0)
                                 > e.context.get("planned", 0))

# capacity starvation -> distributed escalation recovers bitwise
with faults.inject(capacity_scale=0.2):
    p = plan_mod.plan_spgemm(a, b, mesh=mesh, safety=1.3,
                             retry_policy=plan_mod.RetryPolicy(rounds=2))
    res = plan_mod.execute(p, a, b, cache=plan_mod.PlanCache())
c = plan_mod.reassemble(p, res)
pa = plan_mod.plan_spgemm(a, b, safety=64.0, sample_rows=p.sample_rows)
oa = spgemm.spgemm_binned(pa.to_device(a, "a"), pa.to_device(b, "b"),
                          pa.binning, alloc=pa.alloc)
ca = plan_mod.reassemble(pa, oa)
out["capacity"] = dict(
    overflow=int(res.shard_overflow.sum()),
    rpt_eq=bool((c.rpt == ca.rpt).all()),
    col_eq=bool((c.col == ca.col).all()),
    vdiff=float(np.abs(c.val - ca.val).max()),
    ref_err=float(np.abs(c.to_dense() - spgemm_dense_oracle(a, b)).max()),
)
print(json.dumps(out))
"""


def _run(script: str, timeout: int = 900) -> dict:
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_faults_4dev_shard_containment():
    rec = _run(FAULTS_SCRIPT)
    assert rec["exec"]["raised"] and rec["exec"]["unit"] == "dist"
    assert rec["exec"]["cause"] == "InjectedFault"
    assert rec["gather"]["raised"], "gather starvation must not pass silently"
    assert rec["gather"]["has_shard"] and rec["gather"]["has_panel"]
    assert rec["gather"]["starved"]
    assert rec["capacity"]["overflow"] == 0
    assert rec["capacity"]["rpt_eq"] and rec["capacity"]["col_eq"]
    assert rec["capacity"]["vdiff"] < 1e-4
    assert rec["capacity"]["ref_err"] < 1e-3


# --------------------------------------------------------------------------- #
# inject() re-entrancy: hooks restore no matter how the guarded block leaves
# --------------------------------------------------------------------------- #
def test_inject_unwinds_when_block_raises():
    assert not faults.armed()
    with pytest.raises(RuntimeError, match="boom"):
        with faults.inject(capacity_scale=0.5):
            assert faults.armed()
            raise RuntimeError("boom")
    assert not faults.armed()
    assert faults.scale_capacity(100) == 100   # hook fully disarmed


def test_inject_nested_raise_unwinds_in_order():
    # inner block raises; the OUTER context must survive it armed, then
    # disarm cleanly itself — no leak, no premature pop
    with faults.inject(capacity_scale=0.5) as outer:
        with pytest.raises(ValueError):
            with faults.inject(capacity_scale=0.25):
                raise ValueError("inner")
        assert faults._STACK == [outer]
        assert faults.scale_capacity(100) == 50   # outer still armed
    assert not faults.armed()


def test_inject_unwind_pops_by_identity_not_equality():
    # two contexts with IDENTICAL kwargs: exiting the inner one must pop the
    # inner FaultState instance, not an equal-looking outer sibling
    with faults.inject(sketch_scale=0.5, seed=7) as outer:
        with faults.inject(sketch_scale=0.5, seed=7) as inner:
            assert faults._STACK == [outer, inner]
        assert len(faults._STACK) == 1
        assert faults._STACK[0] is outer
    assert not faults.armed()


def test_inject_tolerates_stack_perturbation():
    # a guarded block that itself perturbs the stack (opens a context and
    # leaks past the outer exit) must not break the outer unwind
    rogue = faults.inject(gather_scale=0.5)
    with faults.inject(capacity_scale=0.5):
        rogue.__enter__()                       # now above us on the stack
    # outer removed ITSELF (by identity); the rogue state survives alone
    assert len(faults._STACK) == 1
    assert faults.scale_capacity(100) == 100    # outer truly gone
    rogue.__exit__(None, None, None)
    assert not faults.armed()
