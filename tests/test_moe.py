"""MoE: grouped sort-based dispatch vs a dense-gather reference, capacity
dropping, and the paper-derived expert-capacity predictor."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import smoke_registry
from repro.models import moe as moe_mod
from repro.models.schema import init_params
from repro.core import moe_capacity


def _moe_setup(seed=0, b=2, s=16):
    cfg = smoke_registry()["deepseek-v3-671b"]
    sch = moe_mod.moe_schema(cfg)
    params = init_params(sch, jax.random.PRNGKey(seed), jnp.float32)
    x = jnp.asarray(np.random.default_rng(seed).standard_normal(
        (b, s, cfg.d_model)), jnp.float32)
    return cfg, params, x


def _dense_moe_reference(p, cfg, x):
    """Route every token to its top-k experts by direct gather (no capacity)."""
    e, k = cfg.moe_num_experts, cfg.moe_top_k
    logits = (x @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gates, ids = jax.lax.top_k(probs, k)
    gates = gates / gates.sum(-1, keepdims=True)
    y = jnp.zeros_like(x)
    for j in range(k):
        wi = p["wi"][ids[..., j]]            # (B,S,d,f)
        wg = p["wg"][ids[..., j]]
        wo = p["wo"][ids[..., j]]
        h = jnp.einsum("bsd,bsdf->bsf", x, wi)
        g = jnp.einsum("bsd,bsdf->bsf", x, wg)
        o = jnp.einsum("bsf,bsfd->bsd", jax.nn.silu(g) * h, wo)
        y = y + o * gates[..., j][..., None]
    if "shared" in p:
        from repro.models.layers import apply_mlp
        y = y + apply_mlp(p["shared"], x)
    return y


def test_moe_matches_dense_reference_when_capacity_ample():
    cfg, params, x = _moe_setup()
    y, aux = moe_mod.apply_moe(params, cfg, x, capacity=64)  # no drops
    want = _dense_moe_reference(params, cfg, x)
    assert float(aux.dropped_fraction) == 0.0
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_reported():
    cfg, params, x = _moe_setup(seed=3)
    y, aux = moe_mod.apply_moe(params, cfg, x, capacity=4)
    assert float(aux.dropped_fraction) >= 0.0
    y2, aux2 = moe_mod.apply_moe(params, cfg, x, capacity=64)
    assert float(aux2.dropped_fraction) <= float(aux.dropped_fraction)


def test_moe_aux_losses_finite_and_scaled():
    cfg, params, x = _moe_setup(seed=5)
    _, aux = moe_mod.apply_moe(params, cfg, x, capacity=32)
    # Switch-style LB loss ≈ 1 for uniform routing, ≥1 otherwise
    assert 0.5 < float(aux.load_balance_loss) < 10.0
    assert np.isfinite(float(aux.router_z_loss))
    np.testing.assert_allclose(float(aux.expert_load.sum()), 1.0, rtol=1e-5)


# --------------------------------------------------------------------------- #
# the paper's estimator applied to MoE dispatch (DESIGN §4)
# --------------------------------------------------------------------------- #
def test_dispatch_capacity_prediction_accuracy():
    rng = np.random.default_rng(0)
    tokens, k, e = 200_000, 8, 64
    # skewed routing (zipf-ish expert popularity) — the hard case
    p = (np.arange(1, e + 1) ** -0.8)
    p /= p.sum()
    ids = rng.choice(e, size=(tokens, k), p=p)
    plan = moe_capacity.predict_dispatch_capacity(ids, e, group_size=512,
                                                  seed=1)
    exact = moe_capacity.exact_dispatch_blocks(ids, group_size=512)
    rel = abs(plan.predicted_blocks - exact) / exact
    assert rel < 0.05, f"sampled-CR block prediction off by {rel:.1%}"
    assert plan.block_buffer_size() >= plan.predicted_blocks


def test_dispatch_capacity_jnp_matches_numpy():
    rng = np.random.default_rng(2)
    tokens, k, e = 4096, 2, 16
    ids = rng.integers(0, e, size=(tokens, k))
    groups = jnp.asarray([0, 3, 5], jnp.int32)
    blocks, cr, flopr = moe_capacity.predict_dispatch_capacity_jnp(
        jnp.asarray(ids), e, 256, groups)
    # manual check of the same sampled groups
    f = z = 0
    for g in np.asarray(groups):
        sl = ids[g * 256:(g + 1) * 256].reshape(-1)
        f += sl.size
        z += np.unique(sl).size
    want = tokens * k / (f / z)
    assert float(blocks) == pytest.approx(want, rel=1e-5)
    np.testing.assert_array_equal(
        np.asarray(flopr), np.bincount(ids.reshape(-1), minlength=e))
