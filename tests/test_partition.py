"""Property tests for core/partition.py (hypothesis, with the shim fallback).

Pins the degenerate-input behavior the distributed planner leans on:
``balanced_contiguous`` on all-zero weights / more parts than rows / a single
row, the ``static_row_assignment`` repeat-last pad contract, and the
``shard_slices`` bucket∩shard intersection."""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                    # minimal CI image — deterministic shim
    from hypothesis_shim import given, settings, st

from repro.core.partition import (balanced_contiguous, shard_slices,
                                  static_row_assignment)


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


# --------------------------------------------------------------------------- #
# balanced_contiguous invariants
# --------------------------------------------------------------------------- #
@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=0, max_value=200),
       st.integers(min_value=1, max_value=16),
       st.integers(min_value=0, max_value=3))
def test_balanced_contiguous_invariants(nrows, num_parts, mode):
    rng = _rng(nrows * 31 + num_parts)
    if mode == 0:
        w = np.zeros(nrows)                      # all-zero weights
    elif mode == 1:
        w = rng.random(nrows)
    else:
        w = rng.integers(0, 5, nrows).astype(float)   # many zero rows
    part = balanced_contiguous(w, num_parts)
    bounds = part.bounds
    assert bounds.shape == (num_parts + 1,)
    assert bounds[0] == 0 and bounds[-1] == nrows
    assert (np.diff(bounds) >= 0).all()          # monotone, possibly empty
    # parts tile the rows exactly and the weights are conserved
    np.testing.assert_allclose(part.part_weight.sum(), w.sum(),
                               rtol=1e-9, atol=1e-9)
    for s in range(num_parts):
        np.testing.assert_allclose(part.part_weight[s],
                                   w[bounds[s]:bounds[s + 1]].sum(),
                                   rtol=1e-9, atol=1e-9)
    assert part.imbalance >= 1.0 or w.sum() == 0


def test_balanced_contiguous_degenerate_pins():
    # all-zero weights: every row still assigned, imbalance defined
    part = balanced_contiguous(np.zeros(7), 3)
    assert part.bounds[-1] == 7 and part.imbalance == 1.0
    # more parts than rows: trailing parts empty, never negative ranges
    part = balanced_contiguous(np.ones(2), 5)
    assert part.bounds[-1] == 2
    assert (np.diff(part.bounds) >= 0).all()
    assert int((np.diff(part.bounds) > 0).sum()) <= 2
    # single row: one part owns it, the rest are empty
    part = balanced_contiguous(np.array([3.0]), 4)
    assert part.bounds[-1] == 1
    assert float(part.part_weight.sum()) == 3.0


# --------------------------------------------------------------------------- #
# static_row_assignment: the repeat-last pad contract
# --------------------------------------------------------------------------- #
@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=1, max_value=120),
       st.integers(min_value=1, max_value=8),
       st.integers(min_value=1, max_value=40))
def test_static_row_assignment_pad_contract(nrows, num_parts, rows_per_part):
    rng = _rng(nrows * 13 + num_parts * 7 + rows_per_part)
    part = balanced_contiguous(rng.random(nrows), num_parts)
    table = static_row_assignment(part, rows_per_part)
    assert table.shape == (num_parts, rows_per_part)
    for s in range(num_parts):
        lo, hi = int(part.bounds[s]), int(part.bounds[s + 1])
        n = hi - lo
        if n == 0:
            np.testing.assert_array_equal(table[s], 0)
            continue
        k = min(n, rows_per_part)
        np.testing.assert_array_equal(table[s, :k], np.arange(lo, lo + k))
        # pad slots repeat the LAST row of the range — the contract
        # pad_row_ids-style executors rely on (a pad row never exceeds the
        # range's degree envelope, unlike a row-0 fill)
        np.testing.assert_array_equal(table[s, k:], hi - 1)


# --------------------------------------------------------------------------- #
# shard_slices: bucket∩shard intersection used by the unified planner
# --------------------------------------------------------------------------- #
@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=0, max_value=150),
       st.integers(min_value=1, max_value=6))
def test_shard_slices_tile_the_row_list(nrows, num_parts):
    rng = _rng(nrows * 17 + num_parts)
    rows = np.sort(rng.choice(max(nrows, 1), size=nrows // 2, replace=False)
                   ) if nrows else np.zeros(0, np.int64)
    part = balanced_contiguous(rng.random(nrows), num_parts)
    lo, hi = shard_slices(rows, part.bounds)
    assert (hi >= lo).all()
    pieces = [rows[lo[s]:hi[s]] for s in range(num_parts)]
    np.testing.assert_array_equal(np.concatenate([np.zeros(0, rows.dtype)]
                                                 + pieces), rows)
    for s, piece in enumerate(pieces):
        if piece.size:
            assert piece.min() >= part.bounds[s]
            assert piece.max() < part.bounds[s + 1]
