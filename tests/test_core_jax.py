"""Device (JAX) core vs the numpy oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sparse import random as sprand
from repro.sparse.formats import spgemm_dense_oracle
from repro.core import oracle, csr, flop, predictor, spgemm, partition


@pytest.fixture(scope="module")
def pair():
    a = sprand.banded(400, 400, 10, 14, seed=1)
    b = sprand.erdos_renyi(400, 300, 5, seed=2)
    return a, b, csr.to_device(a), csr.to_device(b)


def test_flop_matches_oracle(pair):
    a, b, ad, bd = pair
    fo, to = oracle.flop_per_row(a, b)
    fj, tj = flop.flop_per_row(ad, bd)
    np.testing.assert_array_equal(fo, np.asarray(fj))
    assert to == int(tj)


def test_flop_capacity_padding_invariant(pair):
    a, b, _, _ = pair
    ad2 = csr.to_device(a, capacity=a.nnz + 1000)  # extra padded slots
    bd2 = csr.to_device(b)
    fj, _ = flop.flop_per_row(ad2, bd2)
    fo, _ = oracle.flop_per_row(a, b)
    np.testing.assert_array_equal(fo, np.asarray(fj))


def test_predictor_matches_oracle_same_rows(pair):
    a, b, ad, bd = pair
    mda, mdb = int(a.row_nnz.max()), int(b.row_nnz.max())
    rows = predictor.draw_sample_rows(jax.random.PRNGKey(3), a.nrows, 40)
    pj = predictor.proposed_predict(ad, bd, rows, mda, mdb)
    po = oracle.proposed_predict(a, b, rows=np.asarray(rows))
    assert abs(float(pj.nnz_total) - po.nnz_total) / po.nnz_total < 1e-5
    assert int(pj.sampled_nnz) == po.sampled_nnz
    assert int(pj.sampled_flop) == po.sampled_flop


def test_reference_matches_oracle_same_rows(pair):
    a, b, ad, bd = pair
    mda, mdb = int(a.row_nnz.max()), int(b.row_nnz.max())
    rows = predictor.draw_sample_rows(jax.random.PRNGKey(5), a.nrows, 40)
    rj = predictor.reference_predict(ad, bd, rows, mda, mdb)
    ro = oracle.reference_predict(a, b, rows=np.asarray(rows))
    assert abs(float(rj.nnz_total) - ro.nnz_total) / ro.nnz_total < 1e-4


def test_full_sample_exact_on_device(pair):
    a, b, ad, bd = pair
    mda, mdb = int(a.row_nnz.max()), int(b.row_nnz.max())
    rows = jnp.arange(a.nrows, dtype=jnp.int32)
    _, z = oracle.exact_structure(a, b)
    pj = predictor.proposed_predict(ad, bd, rows, mda, mdb)
    assert abs(float(pj.nnz_total) - z) / z < 1e-5


def test_numeric_spgemm_with_predicted_allocation(pair):
    """The paper's end-to-end flow: predict → plan → numeric, zero overflow."""
    a, b, ad, bd = pair
    mda, mdb = int(a.row_nnz.max()), int(b.row_nnz.max())
    fo, _ = oracle.flop_per_row(a, b)
    rows = predictor.draw_sample_rows(jax.random.PRNGKey(1), a.nrows, 40)
    pred = predictor.proposed_predict(ad, bd, rows, mda, mdb)
    plan = predictor.AllocationPlan.from_prediction(
        np.asarray(pred.structure), fo, safety=1.4)
    out = spgemm.spgemm(ad, bd, row_capacity=plan.row_capacity,
                        max_deg_a=mda, max_deg_b=mdb, block_rows=64)
    dense = spgemm.dense_of(out, b.ncols)
    np.testing.assert_allclose(np.asarray(dense), spgemm_dense_oracle(a, b),
                               rtol=1e-4, atol=1e-4)
    assert int(out.overflow) == 0
    # never worse than the upper-bound method (this fixture has CR≈1, where
    # the two coincide; the CR≫1 win is asserted in test_system)
    assert plan.row_capacity <= max(int(fo.max()), 8)


def test_spgemm_overflow_reported():
    a = sprand.banded(100, 100, 12, 6, seed=9)   # heavy collisions
    ad = csr.to_device(a)
    mda = int(a.row_nnz.max())
    out = spgemm.spgemm(ad, ad, row_capacity=4, max_deg_a=mda, max_deg_b=mda,
                        block_rows=32)
    assert int(out.overflow) > 0


def test_pad_row_ids_fill_contract():
    """The documented contract: pad slots repeat the LAST listed row."""
    rows = jnp.asarray([7, 3, 9], jnp.int32)
    padded = np.asarray(csr.pad_row_ids(rows, 4))
    np.testing.assert_array_equal(padded, [7, 3, 9, 9])
    np.testing.assert_array_equal(np.asarray(csr.pad_row_ids(rows, 3)),
                                  [7, 3, 9])


def test_spgemm_rows_overflow_independent_of_pad_fill(monkeypatch):
    """Regression (PR 2): overflow must not be inferred from an assumed pad
    fill contract.  The retired closed-form subtracted
    ``max(nnz[last]-cap, 0)·n_pads`` — correct only while every pad row
    duplicates the LAST listed row.  Under any other fill (here: first-row
    fill, with an overflowing first row) that formula miscounts; the
    slice-then-sum derivation stays exact."""
    a = sprand.banded(64, 64, 12, 6, seed=9)
    ad = csr.to_device(a)
    mda = int(a.row_nnz.max())
    nnz = np.asarray(spgemm.spgemm(ad, ad, row_capacity=64, max_deg_a=mda,
                                   max_deg_b=mda, block_rows=16).row_nnz)
    heavy, light = int(nnz.argmax()), int(nnz.argmin())
    cap = int((nnz[heavy] + nnz[light]) // 2)
    assert nnz[heavy] > cap >= nnz[light]          # only `heavy` overflows
    rows = jnp.asarray([heavy, light], jnp.int32)

    def run(block_rows):
        return int(spgemm.spgemm_rows(
            ad, ad, rows, row_capacity=cap, max_deg_a=mda, max_deg_b=mda,
            block_rows=block_rows).overflow)

    want = run(1)                                  # block_rows=1: never pads
    assert want == int(nnz[heavy]) - cap
    assert run(5) == want                          # 3 pads, repeat-last fill

    def pad_first(rows_, multiple):                # adversarial fill contract
        r = rows_.shape[0]
        pad_r = (-(-r // multiple)) * multiple
        rows_ = rows_.astype(jnp.int32)
        if pad_r == r:
            return rows_
        return jnp.concatenate(
            [rows_, jnp.broadcast_to(rows_[:1], (pad_r - r,))])

    monkeypatch.setattr(spgemm, "pad_row_ids", pad_first)
    n_pads = 5                                     # block_rows=7, 2 real rows
    assert run(7) == want
    # the retired formula would have added the pads' overflow (they now
    # duplicate the overflowing FIRST row) and subtracted nothing:
    old_formula = (1 + n_pads) * want - max(int(nnz[light]) - cap, 0) * n_pads
    assert old_formula != want


def test_partition_balance():
    rng = np.random.default_rng(0)
    w = rng.pareto(1.5, size=1000) + 0.1
    part = partition.balanced_contiguous(w, 16)
    assert part.bounds[0] == 0 and part.bounds[-1] == 1000
    assert np.all(np.diff(part.bounds) >= 0)
    # prefix-split guarantee: each part ≤ target + heaviest single row
    bound = 1.0 + w.max() / (w.sum() / 16)
    assert part.imbalance <= bound + 1e-9


def test_partition_straggler_report():
    """Balancing on predicted NNZ beats FLOP balance when CR varies by row."""
    rng = np.random.default_rng(1)
    flopr = np.concatenate([np.full(500, 100.0), np.full(500, 100.0)])
    nnzr = np.concatenate([np.full(500, 100.0), np.full(500, 5.0)])  # CR 20 tail
    p_flop = partition.balanced_contiguous(flopr, 8)
    # accumulation work tracks nnz: measure nnz imbalance under flop bounds
    nnz_under_flop = np.add.reduceat(nnzr, p_flop.bounds[:-1])
    imb_flop = nnz_under_flop.max() / nnz_under_flop.mean()
    p_pred = partition.balanced_contiguous(nnzr, 8)
    assert p_pred.imbalance < imb_flop
