"""Distributed SpGEMM (shard_map + predicted-NNZ balance) on a 4-device mesh.

Subprocess (device-count env must precede jax init)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax

from repro.sparse import random as sprand
from repro.sparse.formats import spgemm_dense_oracle
from repro.core import distributed, oracle

a = sprand.banded(600, 600, 18, 16, seed=5)
b = sprand.banded(600, 600, 12, 20, seed=6)
mesh = jax.make_mesh((4,), ("data",))
plan = distributed.plan_distributed(a, b, num_shards=4)
col, val, row_nnz, ofl = distributed.distributed_spgemm(a, b, mesh, plan)
c = distributed.reassemble(plan, col, val, np.asarray(row_nnz), b.ncols)
ref = spgemm_dense_oracle(a, b)
err = float(np.abs(c.to_dense() - ref).max())
_, z = oracle.exact_structure(a, b)
flopr, _ = oracle.flop_per_row(a, b)
print(json.dumps(dict(err=err, overflow=int(np.asarray(ofl).sum()),
                      nnz=c.nnz, z=z, imbalance=plan.partition.imbalance,
                      cap=plan.row_capacity, ub=int(flopr.max()))))
"""


@pytest.mark.slow
def test_distributed_spgemm_4dev():
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["overflow"] == 0
    assert rec["err"] < 1e-3
    assert rec["nnz"] == rec["z"]
    assert rec["imbalance"] < 1.2          # predicted-NNZ balance held
    assert rec["cap"] < rec["ub"]          # beat the upper-bound allocation
