"""Distributed SpGEMM on a 4-device mesh: legacy global-pad baseline plus the
unified plan/execute pipeline (core/plan.py).

The legacy path is RETIRED from the library (PR 5): it lives at
``benchmarks/legacy_distributed.py`` as the benchmark baseline, so its
coverage here imports it from there (``sys.path`` injection — the
benchmarks directory is not a package on the library path).

Mesh tests run in subprocesses (device-count env must precede jax init);
host-only legacy fixes (reassemble on all-empty outputs, overflow
surfacing) run in-process."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
BENCH = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                     "benchmarks"))


def _legacy():
    """Import the retired global-pad baseline from its benchmarks home."""
    if BENCH not in sys.path:
        sys.path.insert(0, BENCH)
    import legacy_distributed
    return legacy_distributed


SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import sys
import numpy as np
import jax

sys.path.insert(0, os.environ["BENCH_DIR"])
import legacy_distributed as distributed
from repro.sparse import random as sprand
from repro.sparse.formats import spgemm_dense_oracle
from repro.core import oracle

a = sprand.banded(600, 600, 18, 16, seed=5)
b = sprand.banded(600, 600, 12, 20, seed=6)
mesh = jax.make_mesh((4,), ("data",))
plan = distributed.plan_distributed(a, b, num_shards=4)
col, val, row_nnz, ofl = distributed.distributed_spgemm(a, b, mesh, plan)
c = distributed.reassemble(plan, col, val, np.asarray(row_nnz), b.ncols,
                           overflow=np.asarray(ofl))
ref = spgemm_dense_oracle(a, b)
err = float(np.abs(c.to_dense() - ref).max())
_, z = oracle.exact_structure(a, b)
flopr, _ = oracle.flop_per_row(a, b)
print(json.dumps(dict(err=err, overflow=int(np.asarray(ofl).sum()),
                      nnz=c.nnz, z=z, imbalance=plan.partition.imbalance,
                      cap=plan.row_capacity, ub=int(flopr.max()))))
"""

# The acceptance contract of the unified pipeline (ISSUE 3): on every suite
# family the distributed binned-routed path must match single-device
# spgemm_binned bitwise on symbolic counts (row_nnz/col) and to float
# tolerance on values; the plan cache must serve a second same-signature
# pair with ZERO executor retraces.
PLAN_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax

from repro.sparse import random as sprand
from repro.sparse.formats import CSR, spgemm_dense_oracle
from repro.core import plan as plan_mod, spgemm

def revalue(m, seed):
    rng = np.random.default_rng(seed)
    return CSR(rpt=m.rpt.copy(), col=m.col.copy(),
               val=rng.standard_normal(m.nnz).astype(np.float32),
               shape=m.shape)

mesh = jax.make_mesh((4,), ("data",))
fams = [
    ("er", sprand.erdos_renyi(500, 500, 4, seed=25),
     sprand.erdos_renyi(500, 500, 3, seed=26)),
    ("pl", sprand.power_law(700, 700, 5, 1.5, seed=21),
     sprand.power_law(700, 700, 4, 1.6, seed=22)),
    ("rmat", sprand.rmat(500, 500, 2500, seed=31),
     sprand.rmat(500, 500, 2000, seed=32)),
    ("band", sprand.banded(600, 600, 18, 16, seed=5),
     sprand.banded(600, 600, 12, 20, seed=6)),
    ("fem", sprand.banded(400, 400, 40, 30, seed=51),
     sprand.banded(400, 400, 32, 28, seed=52)),
]
out = {}
for fam, a, b in fams:
    use_kernel = fam == "band"      # routed Pallas dispatch under shard_map
    p = plan_mod.plan_spgemm(a, b, mesh=mesh, safety=2.0,
                             use_kernel=use_kernel)
    res = plan_mod.execute(p, a, b)
    c = plan_mod.reassemble(p, res)
    # single-device binned reference, same sample/safety
    pl = plan_mod.plan_spgemm(a, b, safety=2.0, sample_rows=p.sample_rows)
    cl = plan_mod.reassemble(pl, plan_mod.execute(pl, a, b))
    assert (c.rpt == cl.rpt).all(), fam + ": symbolic row counts differ"
    assert (c.col == cl.col).all(), fam + ": columns differ"
    vdiff = float(np.abs(c.val - cl.val).max())
    ref_err = float(np.abs(c.to_dense() - spgemm_dense_oracle(a, b)).max())
    out[fam] = dict(vdiff=vdiff, ref_err=ref_err,
                    overflow=int(res.shard_overflow.sum()),
                    imbalance=round(float(p.partition.imbalance), 4))

# plan-cache serving contract: same-signature pair, zero retraces
cache = plan_mod.PlanCache()
fam, a, b = fams[3]
p1 = plan_mod.plan_spgemm(a, b, mesh=mesh, safety=2.0)
plan_mod.execute(p1, a, b, cache=cache)
t0 = cache.stats()["traces"]
a2, b2 = revalue(a, 91), revalue(b, 92)
p2 = plan_mod.plan_spgemm(a2, b2, mesh=mesh, safety=2.0)
assert p2.key == p1.key, "serving pair changed the plan key"
res2 = plan_mod.execute(p2, a2, b2, cache=cache)
c2 = plan_mod.reassemble(p2, res2)
err2 = float(np.abs(c2.to_dense() - spgemm_dense_oracle(a2, b2)).max())
out["cache"] = dict(retraces=cache.stats()["traces"] - t0,
                    hits=cache.stats()["hits"], err2=err2)
print(json.dumps(out))
"""


def _run(script: str, timeout: int = 900) -> dict:
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu",
               BENCH_DIR=BENCH)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_distributed_spgemm_4dev():
    rec = _run(SCRIPT)
    assert rec["overflow"] == 0
    assert rec["err"] < 1e-3
    assert rec["nnz"] == rec["z"]
    assert rec["imbalance"] < 1.2          # predicted-NNZ balance held
    assert rec["cap"] < rec["ub"]          # beat the upper-bound allocation


@pytest.mark.slow
def test_plan_execute_matches_single_device_on_all_families():
    rec = _run(PLAN_SCRIPT)
    for fam in ("er", "pl", "rmat", "band", "fem"):
        assert rec[fam]["overflow"] == 0, (fam, rec[fam])
        assert rec[fam]["vdiff"] < 1e-4, (fam, rec[fam])
        assert rec[fam]["ref_err"] < 1e-3, (fam, rec[fam])
    assert rec["cache"]["retraces"] == 0, rec["cache"]
    assert rec["cache"]["hits"] >= 1
    assert rec["cache"]["err2"] < 1e-3


# --------------------------------------------------------------------------- #
# legacy-path fixes (host-only, no mesh needed)
# --------------------------------------------------------------------------- #
def _empty_plan(num_shards=2, rows_per_shard=3):
    from repro.core import partition
    distributed = _legacy()
    part = partition.balanced_contiguous(np.zeros(0), num_shards)
    table = np.zeros((num_shards, rows_per_shard), np.int32)
    valid = np.zeros((num_shards, rows_per_shard), bool)
    return distributed.DistSpGEMMPlan(table, valid, 8, part, 0.0)


def test_legacy_not_importable_from_the_library():
    """The global-pad shard path is retired: ``repro.core.distributed`` no
    longer exists — the baseline lives only under benchmarks/."""
    with pytest.raises(ImportError):
        from repro.core import distributed  # noqa: F401


def test_reassemble_all_empty_shard_outputs():
    """No valid rows at all (every shard empty) must reassemble to an empty
    CSR instead of crashing np.concatenate on an empty list."""
    distributed = _legacy()
    plan = _empty_plan()
    col = np.full((2, 3, 8), np.iinfo(np.int32).max, np.int32)
    val = np.zeros((2, 3, 8), np.float32)
    c = distributed.reassemble(plan, col, val, np.zeros((2, 3), np.int32), 4)
    assert c.nnz == 0 and c.shape == (0, 4)


def test_reassemble_surfaces_overflow():
    from repro.core import partition
    distributed = _legacy()
    part = partition.balanced_contiguous(np.ones(2), 1)
    plan = distributed.DistSpGEMMPlan(
        np.array([[0, 1]], np.int32), np.ones((1, 2), bool), 2, part, 4.0)
    col = np.array([[[0, 1], [2, 3]]], np.int32)
    val = np.ones((1, 2, 2), np.float32)
    nnz = np.array([[3, 2]], np.int32)      # row 0 truly has 3 → 1 dropped
    with pytest.raises(ValueError, match="overflow"):
        distributed.reassemble(plan, col, val, nnz, 4,
                               overflow=np.array([1]))
    # legacy call shape (no overflow arg) and explicit ignore still work
    c = distributed.reassemble(plan, col, val, nnz, 4)
    c2 = distributed.reassemble(plan, col, val, nnz, 4,
                                overflow=np.array([1]),
                                on_overflow="ignore")
    assert c.nnz == c2.nnz == 4
