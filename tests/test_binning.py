"""Degree-binned pipeline vs global-pad: plan invariants + exact equality.

The binned paths must be *bitwise* interchangeable with the global-pad paths
(same eq. 2 / eq. 4 semantics, same numeric output), on mixed-skew inputs and
the hub-row / empty-bucket edge cases."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sparse import random as sprand
from repro.sparse.formats import CSR, spgemm_dense_oracle
from repro.core import binning, csr, predictor, spgemm
from repro.core.flop import flop_per_row


def _mixed_skew_cases():
    return [
        ("pl", sprand.power_law(700, 700, 5, 1.5, seed=21),
         sprand.power_law(700, 700, 4, 1.6, seed=22)),
        ("band", sprand.banded(500, 500, 10, 14, seed=23),
         sprand.banded(500, 500, 8, 12, seed=24)),
        ("er", sprand.erdos_renyi(400, 400, 4, seed=25),
         sprand.erdos_renyi(400, 400, 3, seed=26)),
        ("pl_x_band", sprand.power_law(500, 500, 5, 1.4, seed=27),
         sprand.banded(500, 500, 8, 12, seed=28)),
    ]


def _hub_matrix(m=400, hub_deg=200):
    """Degree-2 matrix with a single hub row — worst case for global pad."""
    rng = np.random.default_rng(0)
    rows = np.repeat(np.arange(1, m), 2)
    cols = rng.integers(0, m, rows.size)
    hub_cols = rng.choice(m, hub_deg, replace=False)
    rows = np.concatenate([np.zeros(hub_deg, np.int64), rows])
    cols = np.concatenate([hub_cols, cols])
    vals = rng.standard_normal(rows.size).astype(np.float32)
    return CSR.from_coo(rows, cols, vals, (m, m))


# --------------------------------------------------------------------------- #
# plan invariants
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name,a,b", _mixed_skew_cases(),
                         ids=[c[0] for c in _mixed_skew_cases()])
def test_plan_partitions_rows_and_bounds_degrees(name, a, b):
    plan = binning.build_plan(a, b)
    allrows = np.sort(np.concatenate([bk.rows for bk in plan.buckets]))
    np.testing.assert_array_equal(allrows, np.arange(a.nrows))
    deg_a, dbmax, _ = binning.row_widths(a.rpt, a.col, np.diff(b.rpt))
    for i, bk in enumerate(plan.buckets):
        assert int(deg_a[bk.rows].max()) <= bk.deg_a
        assert int(dbmax[bk.rows].max()) <= bk.deg_b
        assert bk.block_rows * binning.ceil_pow2(bk.width) <= \
            binning.DEFAULT_LANE_BUDGET or bk.block_rows == 1
        np.testing.assert_array_equal(plan.row_bucket[bk.rows], i)


def test_plan_never_processes_more_lanes_than_global():
    for _, a, b in _mixed_skew_cases():
        plan = binning.build_plan(a, b)
        assert plan.lanes <= plan.global_lanes


def test_hub_row_isolated_and_cheap():
    a = _hub_matrix()
    plan = binning.build_plan(a, a)
    # the hub must not drag the low-degree rows up to its width
    assert plan.lane_reduction > 5.0
    hub_bucket = plan.buckets[int(plan.row_bucket[0])]
    assert hub_bucket.n_rows < 50  # hub rides in a small top bucket


def test_subset_preserves_duplicates_and_empty_buckets():
    a = _hub_matrix()
    plan = binning.build_plan(a, a)
    assert len(plan.buckets) >= 2           # hub separates from the bulk
    rows = np.array([5, 5, 7])              # duplicates (sampling w/ replace)
    sub = plan.subset(rows)
    assert sum(s.size for s in sub) == rows.size
    # all samples come from row 5/7's bucket(s); the hub bucket stays empty
    assert sub[int(plan.row_bucket[0])].size == 0
    hub_sub = plan.subset(np.array([0]))[int(plan.row_bucket[0])]
    assert 0 in hub_sub


# --------------------------------------------------------------------------- #
# binned predictor == global predictor (bitwise)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name,a,b", _mixed_skew_cases(),
                         ids=[c[0] for c in _mixed_skew_cases()])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_binned_predict_matches_global(name, a, b, use_kernel):
    ad, bd = csr.to_device(a), csr.to_device(b)
    mda, mdb = int(a.row_nnz.max()), int(b.row_nnz.max())
    plan = binning.build_plan(a, b)
    rows = predictor.draw_sample_rows(
        jax.random.PRNGKey(3), a.nrows, predictor.static_sample_num(a.nrows))
    pg = predictor.proposed_predict(ad, bd, rows, mda, mdb)
    pb = predictor.proposed_predict_binned(ad, bd, rows, plan,
                                           use_kernel=use_kernel)
    assert int(pg.sampled_nnz) == int(pb.sampled_nnz)
    assert int(pg.sampled_flop) == int(pb.sampled_flop)
    assert float(pg.nnz_total) == float(pb.nnz_total)
    assert float(pg.compression_ratio) == float(pb.compression_ratio)
    np.testing.assert_array_equal(np.asarray(pg.structure),
                                  np.asarray(pb.structure))


def test_binned_reference_predict_matches_global():
    for _, a, b in _mixed_skew_cases()[:2]:
        ad, bd = csr.to_device(a), csr.to_device(b)
        mda, mdb = int(a.row_nnz.max()), int(b.row_nnz.max())
        plan = binning.build_plan(a, b)
        rows = predictor.draw_sample_rows(jax.random.PRNGKey(1), a.nrows, 40)
        rg = predictor.reference_predict(ad, bd, rows, mda, mdb)
        rb = predictor.reference_predict_binned(ad, bd, rows, plan)
        assert float(rg.nnz_total) == float(rb.nnz_total)
        np.testing.assert_array_equal(np.asarray(rg.structure),
                                      np.asarray(rb.structure))


def test_binned_predict_hub_row_case():
    a = _hub_matrix()
    ad = csr.to_device(a)
    mda = int(a.row_nnz.max())
    plan = binning.build_plan(a, a)
    rows = jnp.asarray(np.array([0, 1, 2, 399], np.int32))  # hub sampled
    pg = predictor.proposed_predict(ad, ad, rows, mda, mda)
    pb = predictor.proposed_predict_binned(ad, ad, rows, plan)
    assert float(pg.nnz_total) == float(pb.nnz_total)


# --------------------------------------------------------------------------- #
# binned numeric == global numeric (bitwise at uniform capacity)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name,a,b", _mixed_skew_cases(),
                         ids=[c[0] for c in _mixed_skew_cases()])
def test_binned_spgemm_bitwise_equal(name, a, b):
    ad, bd = csr.to_device(a), csr.to_device(b)
    mda, mdb = int(a.row_nnz.max()), int(b.row_nnz.max())
    plan = binning.build_plan(a, b)
    floprc, _ = flop_per_row(ad, bd)
    rows = predictor.draw_sample_rows(jax.random.PRNGKey(0), a.nrows, 60)
    pred = predictor.proposed_predict(ad, bd, rows, mda, mdb)
    alloc = predictor.AllocationPlan.from_prediction(
        np.asarray(pred.structure), np.asarray(floprc), safety=1.3)
    og = spgemm.spgemm(ad, bd, row_capacity=alloc.row_capacity,
                       max_deg_a=mda, max_deg_b=mdb, block_rows=64)
    ob = spgemm.spgemm_binned(ad, bd, plan, alloc=alloc.row_capacity)
    np.testing.assert_array_equal(np.asarray(og.col), np.asarray(ob.col))
    np.testing.assert_array_equal(np.asarray(og.val), np.asarray(ob.val))
    np.testing.assert_array_equal(np.asarray(og.row_nnz),
                                  np.asarray(ob.row_nnz))
    assert int(og.overflow) == int(ob.overflow)


def test_binned_spgemm_kernel_route_matches_jnp_route():
    _, a, b = _mixed_skew_cases()[0]
    ad, bd = csr.to_device(a), csr.to_device(b)
    plan = binning.build_plan(a, b)
    floprc, _ = flop_per_row(ad, bd)
    rows = predictor.draw_sample_rows(jax.random.PRNGKey(0), a.nrows, 60)
    pred = predictor.proposed_predict_binned(ad, bd, rows, plan)
    balloc = predictor.BinnedAllocationPlan.from_prediction(
        plan, np.asarray(pred.structure), np.asarray(floprc), safety=1.5)
    oj = spgemm.spgemm_binned(ad, bd, plan, alloc=balloc, use_kernel=False)
    ok = spgemm.spgemm_binned(ad, bd, plan, alloc=balloc, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(oj.col), np.asarray(ok.col))
    np.testing.assert_allclose(np.asarray(oj.val), np.asarray(ok.val),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(oj.row_nnz),
                                  np.asarray(ok.row_nnz))
    assert int(oj.overflow) == int(ok.overflow)


def test_binned_spgemm_values_correct_with_binned_alloc():
    """Per-bucket capacities: smaller buffers, same product values."""
    a = _hub_matrix(300, 120)
    ad = csr.to_device(a)
    plan = binning.build_plan(a, a)
    floprc, _ = flop_per_row(ad, ad)
    rows = predictor.draw_sample_rows(jax.random.PRNGKey(2), a.nrows, 50)
    pred = predictor.proposed_predict_binned(ad, ad, rows, plan)
    balloc = predictor.BinnedAllocationPlan.from_prediction(
        plan, np.asarray(pred.structure), np.asarray(floprc), safety=2.0)
    out = spgemm.spgemm_binned(ad, ad, plan, alloc=balloc)
    assert int(out.overflow) == 0
    np.testing.assert_allclose(np.asarray(spgemm.dense_of(out, a.ncols)),
                               spgemm_dense_oracle(a, a), rtol=1e-4, atol=1e-4)
    # the binned total allocation must not exceed the uniform-cap one
    uni = predictor.AllocationPlan.from_prediction(
        np.asarray(pred.structure), np.asarray(floprc), safety=2.0)
    assert balloc.total_capacity <= uni.row_capacity * a.nrows
    assert max(balloc.bucket_capacities) <= uni.row_capacity


def test_empty_rows_and_single_bucket_edge():
    """Matrix with empty rows (deg 0) still round-trips the binned paths."""
    rpt = np.array([0, 0, 2, 2, 4, 4], np.int64)
    col = np.array([1, 3, 0, 2], np.int32)
    val = np.ones(4, np.float32)
    a = CSR(rpt=rpt, col=col, val=val, shape=(5, 5))
    ad = csr.to_device(a)
    plan = binning.build_plan(a, a, min_rows=1)
    mda = int(a.row_nnz.max())
    og = spgemm.spgemm(ad, ad, row_capacity=8, max_deg_a=mda, max_deg_b=mda)
    ob = spgemm.spgemm_binned(ad, ad, plan, alloc=8)
    np.testing.assert_array_equal(np.asarray(og.col), np.asarray(ob.col))
    np.testing.assert_array_equal(np.asarray(og.row_nnz),
                                  np.asarray(ob.row_nnz))


def test_partition_binned_cost_weights():
    from repro.core.partition import balanced_contiguous, binned_cost_weights
    a = _hub_matrix()
    plan = binning.build_plan(a, a)
    w = binned_cost_weights(plan)
    assert w.shape == (a.nrows,)
    assert w[0] == max(bk.width for bk in plan.buckets)  # hub pays hub width
    part = balanced_contiguous(w, 4)
    assert part.imbalance >= 1.0


def test_compile_cache_signature_reuse():
    """Equal-shaped matrices from the same family share every signature —
    the static half of the jit cache key (full reuse additionally needs
    matching bucket populations; see core.binning docstring)."""
    a1 = sprand.banded(400, 400, 8, 12, seed=31)
    a2 = sprand.banded(400, 400, 8, 12, seed=32)
    p1 = binning.build_plan(a1, a1)
    p2 = binning.build_plan(a2, a2)
    assert p1.signatures() == p2.signatures()
