"""CSR substrate: roundtrips, the paper's reshape rule, generators."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal CI image — deterministic tests must still run
    from hypothesis_shim import given, settings, st

from repro.sparse.formats import CSR, match_dims
from repro.sparse import random as sprand


def test_dense_roundtrip():
    rng = np.random.default_rng(0)
    a = (rng.random((13, 17)) < 0.3) * rng.standard_normal((13, 17))
    c = CSR.from_dense(a.astype(np.float32))
    np.testing.assert_allclose(c.to_dense(), a.astype(np.float32))


def test_coo_dedup_sums():
    c = CSR.from_coo(np.array([0, 0, 1]), np.array([2, 2, 0]),
                     np.array([1.0, 2.0, 5.0], np.float32), (2, 3))
    assert c.nnz == 2
    d = c.to_dense()
    assert d[0, 2] == 3.0 and d[1, 0] == 5.0


def test_reshape_rule_left_cols():
    """Paper VI-A: 10x10 × 5x5 → keep left 5 columns of A."""
    rng = np.random.default_rng(1)
    a = CSR.from_dense((rng.random((10, 10)) < 0.5).astype(np.float32))
    b = CSR.from_dense((rng.random((5, 5)) < 0.5).astype(np.float32))
    am, bm = match_dims(a, b)
    assert am.shape == (10, 5) and bm.shape == (5, 5)
    np.testing.assert_allclose(am.to_dense(), a.to_dense()[:, :5])


def test_reshape_rule_top_rows():
    rng = np.random.default_rng(2)
    a = CSR.from_dense((rng.random((5, 5)) < 0.5).astype(np.float32))
    b = CSR.from_dense((rng.random((10, 10)) < 0.5).astype(np.float32))
    am, bm = match_dims(a, b)
    assert am.shape == (5, 5) and bm.shape == (5, 10)
    np.testing.assert_allclose(bm.to_dense(), b.to_dense()[:5])


@given(st.integers(10, 200), st.integers(1, 8), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_generator_invariants(m, d, seed):
    a = sprand.erdos_renyi(m, m, d, seed)
    assert a.shape == (m, m)
    assert a.nnz == a.rpt[-1] == len(a.col)
    # sorted, in-range columns per row
    for i in range(0, m, max(1, m // 7)):
        cols = a.col[a.rpt[i]:a.rpt[i + 1]]
        assert np.all(np.diff(cols) > 0)
        assert cols.size == 0 or (cols.min() >= 0 and cols.max() < m)


def test_banded_band_respected():
    a = sprand.banded(100, 100, 8, 5, seed=3)
    rows = np.repeat(np.arange(100), a.row_nnz)
    assert np.all(np.abs(a.col - rows) <= 5)


def test_suite_mini_cr_spread():
    """The synthetic families must span low→high CR like Table II."""
    from repro.sparse.suite import mini_suite
    from repro.core import oracle
    crs = {}
    for name, m in mini_suite():
        _, f = oracle.flop_per_row(m, m)
        _, z = oracle.exact_structure(m, m)
        crs[name] = f / z
    assert crs["mini_er"] < 1.5
    assert crs["mini_fem"] > 5.0
