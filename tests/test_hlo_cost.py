"""The trip-count-aware HLO cost model vs known-flop programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import hlo_cost, analysis


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_single_dot_flops():
    x = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    c = _compile(lambda a: a @ a, x)
    r = hlo_cost.analyze(c.as_text())
    assert r["flops"] == pytest.approx(2 * 512 ** 3, rel=1e-6)


def test_scan_multiplies_by_trip_count():
    def f(a):
        y, _ = jax.lax.scan(lambda c, _: (c @ c, None), a, None, length=10)
        return y
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = _compile(f, x)
    r = hlo_cost.analyze(c.as_text())
    assert r["flops"] == pytest.approx(10 * 2 * 256 ** 3, rel=1e-6)
    # xla's own analysis undercounts — that's why this module exists
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    assert ca["flops"] < r["flops"] / 2


def test_nested_scan_multiplies():
    def f(a):
        def outer(c, _):
            y, _ = jax.lax.scan(lambda d, _: (d @ d, None), c, None, length=3)
            return y, None
        y, _ = jax.lax.scan(outer, a, None, length=4)
        return y
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = _compile(f, x)
    r = hlo_cost.analyze(c.as_text())
    assert r["flops"] == pytest.approx(12 * 2 * 128 ** 3, rel=1e-6)


def test_batched_dot_flops():
    x = jax.ShapeDtypeStruct((8, 64, 96), jnp.float32)
    y = jax.ShapeDtypeStruct((8, 96, 32), jnp.float32)
    c = _compile(lambda a, b: jnp.einsum("bik,bkj->bij", a, b), x, y)
    r = hlo_cost.analyze(c.as_text())
    assert r["flops"] == pytest.approx(2 * 8 * 64 * 96 * 32, rel=1e-6)


def test_bytes_nonzero_and_sane():
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    c = _compile(lambda a: a @ a + 1.0, x)
    r = hlo_cost.analyze(c.as_text())
    sz = 1024 * 1024 * 4
    assert r["bytes"] >= 2 * sz            # at least read + write
    assert r["bytes"] < 50 * sz            # and not absurd


def test_roofline_terms_and_bottleneck():
    rl = analysis.Roofline.build(
        flops_per_chip=1.97e12,            # 10 ms of compute
        hbm_bytes_per_chip=819e6,          # 1 ms of HBM
        coll={"all-reduce": 50e6},         # 1 ms of ICI
        model_flops=1.97e12 * 256 * 0.5, chips=256)
    assert rl.compute_s == pytest.approx(0.01)
    assert rl.memory_s == pytest.approx(0.001)
    assert rl.collective_s == pytest.approx(0.001)
    assert rl.bottleneck == "compute"
    assert rl.useful_flops_ratio == pytest.approx(0.5)
