"""SpGEMM service scheduler suite (DESIGN.md §10).

The contract under test: every submitted request reaches a terminal state
with either a bitwise-correct result (vs an ample-capacity reference on
the same sampled rows) or a typed :mod:`repro.core.errors` error — under
no-fault traffic AND under the full chaos matrix (all five
:mod:`repro.core.faults` classes) — and the queue always drains.  The
no-fault steady state is compile-count pinned: repeat templates add ZERO
executor retraces.
"""
import numpy as np
import pytest

from repro.core import faults, plan as plan_mod, spgemm
from repro.core.errors import (AdmissionRejectedError, CapacityExhaustedError,
                               DeadlineExceededError, OperandValidationError,
                               ShardFailureError, SpgemmError)
from repro.serve.spgemm_service import (CircuitBreaker, Request, RequestState,
                                        ServiceConfig, SpgemmService)
from repro.sparse import random as sprand
from repro.sparse.formats import CSR, spgemm_dense_oracle


import jax


@pytest.fixture(scope="module", autouse=True)
def _release_executables():
    """This module compiles many short-lived service executors (chaos
    retraces, per-config caches).  Drop them from jax's global caches on
    the way out so a long single-process suite run doesn't accumulate
    native compiler state across modules."""
    yield
    jax.clear_caches()


def _families():
    return [
        ("er", sprand.erdos_renyi(250, 250, 4, seed=25),
         sprand.erdos_renyi(250, 250, 3, seed=26)),
        ("pl", sprand.power_law(300, 300, 5, 1.5, seed=21),
         sprand.power_law(300, 300, 4, 1.6, seed=22)),
        ("rmat", sprand.rmat(250, 250, 1250, seed=31),
         sprand.rmat(250, 250, 1000, seed=32)),
        ("band", sprand.banded(250, 250, 10, 14, seed=23),
         sprand.banded(250, 250, 8, 12, seed=24)),
        ("fem", sprand.banded(160, 160, 40, 30, seed=51),
         sprand.banded(160, 160, 32, 28, seed=52)),
    ]


def _reference(p, a, b):
    """Ample-capacity binned run on the same sample rows — the bitwise
    ground truth a served result must match."""
    pa = plan_mod.plan_spgemm(a, b, safety=64.0, sample_rows=p.sample_rows)
    oa = spgemm.spgemm_binned(pa.to_device(a, "a"), pa.to_device(b, "b"),
                              pa.binning, alloc=pa.alloc)
    assert int(oa.overflow) == 0, "reference must not overflow"
    return plan_mod.reassemble(pa, oa)


def _assert_bitwise(req, a, b):
    c, ca = req.result, _reference(req.plan, a, b)
    np.testing.assert_array_equal(c.rpt, ca.rpt)
    np.testing.assert_array_equal(c.col, ca.col)
    np.testing.assert_allclose(c.val, ca.val, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(c.to_dense(), spgemm_dense_oracle(a, b),
                               rtol=1e-4, atol=1e-4)


class FakeClock:
    """Deterministic service clock: deadline behavior becomes a pure
    function of explicit ``advance`` calls."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _nan_matrix() -> CSR:
    m = sprand.erdos_renyi(50, 50, 3, seed=7)
    val = m.val.copy()
    val[len(val) // 2] = np.nan
    return CSR(rpt=m.rpt, col=m.col, val=val, shape=m.shape)


# --------------------------------------------------------------------------- #
# lifecycle
# --------------------------------------------------------------------------- #
def test_clean_request_lifecycle_and_history():
    _, a, b = _families()[0]
    svc = SpgemmService()
    req = svc.submit(a, b)
    assert req.state == RequestState.ADMITTED
    assert not req.done
    svc.drain()
    assert req.state == RequestState.DONE
    assert [s for s, _ in req.history] == [
        RequestState.SUBMITTED, RequestState.ADMITTED, RequestState.PLANNED,
        RequestState.EXECUTING, RequestState.DONE]
    assert req.latency is not None and req.latency >= 0
    assert req.stats["degradations"] == [] and req.stats["retries"] == 0
    assert req.stats["estimate"]["total_bytes"] > 0
    _assert_bitwise(req, a, b)
    assert req.result_or_raise() is req.result


def test_every_terminal_state_carries_result_xor_typed_error():
    _, a, b = _families()[0]
    svc = SpgemmService(ServiceConfig(queue_capacity=1))
    ok = svc.submit(a, b)
    shed = svc.submit(a, b)                     # queue_capacity=1 → shed
    bad = svc.submit(_nan_matrix(), _nan_matrix())
    svc.drain()
    assert ok.result is not None and ok.error is None
    for r in (shed, bad):
        assert r.result is None and isinstance(r.error, SpgemmError)
        with pytest.raises(SpgemmError):
            r.result_or_raise()
    assert isinstance(shed.error, AdmissionRejectedError)
    assert shed.error.context["reason"] == "queue_full"
    assert isinstance(bad.error, OperandValidationError)
    assert bad.state == RequestState.FAILED


def test_result_or_raise_rejects_non_terminal():
    _, a, b = _families()[0]
    svc = SpgemmService()
    req = svc.submit(a, b)
    with pytest.raises(SpgemmError, match="not terminal"):
        req.result_or_raise()
    svc.drain()


# --------------------------------------------------------------------------- #
# batching + zero-retrace steady state
# --------------------------------------------------------------------------- #
def test_same_template_requests_batch_one_wave():
    _, a, b = _families()[0]
    svc = SpgemmService(ServiceConfig(max_batch=8))
    reqs = [svc.submit(a, b) for _ in range(5)]
    done = svc.step()
    assert len(done) == 5                       # one wave served the batch
    assert svc.stats()["waves"] == 1
    assert all(r.state == RequestState.DONE for r in reqs)


def test_repeat_templates_add_zero_retraces():
    fams = _families()
    svc = SpgemmService()
    for _, a, b in fams:
        svc.submit(a, b)
    svc.drain()
    traces = svc.stats()["plan_cache"]["traces"]
    reqs = [svc.submit(a, b) for _, a, b in fams for _ in range(3)]
    svc.drain()
    assert svc.stats()["plan_cache"]["traces"] == traces, \
        "steady-state repeat traffic must not retrace"
    assert all(r.state == RequestState.DONE for r in reqs)


def test_mixed_shapes_do_not_cross_batch():
    fams = _families()
    svc = SpgemmService(ServiceConfig(max_batch=8))
    a0, b0 = fams[0][1], fams[0][2]
    a4, b4 = fams[4][1], fams[4][2]
    order = [svc.submit(a0, b0), svc.submit(a4, b4), svc.submit(a0, b0)]
    done = svc.step()
    # wave 1: both er requests batch; the fem request keeps its queue slot
    assert {r.id for r in done} == {order[0].id, order[2].id}
    assert order[1].state == RequestState.ADMITTED
    svc.drain()
    assert order[1].state == RequestState.DONE


# --------------------------------------------------------------------------- #
# shedding, deadlines, budget
# --------------------------------------------------------------------------- #
def test_queue_full_sheds_with_typed_error():
    _, a, b = _families()[0]
    svc = SpgemmService(ServiceConfig(queue_capacity=2))
    kept = [svc.submit(a, b) for _ in range(2)]
    shed = [svc.submit(a, b) for _ in range(3)]
    assert all(r.state == RequestState.SHED for r in shed)
    assert all(r.error.context["observed"] == 2 for r in shed)
    assert svc.stats()["queue"]["shed"] == 3
    svc.drain()
    assert all(r.state == RequestState.DONE for r in kept)


def test_deadline_expires_while_queued():
    _, a, b = _families()[0]
    clk = FakeClock()
    svc = SpgemmService(ServiceConfig(), clock=clk)
    urgent = svc.submit(a, b, deadline=5.0)
    patient = svc.submit(a, b)
    clk.advance(10.0)
    done = svc.drain()
    assert urgent.state == RequestState.EXPIRED
    assert isinstance(urgent.error, DeadlineExceededError)
    assert urgent.error.context["deadline"] == 5.0
    assert urgent.error.context["observed"] >= 10.0
    assert patient.state == RequestState.DONE
    assert {r.id for r in done} == {urgent.id, patient.id}
    assert svc.stats()["queue"]["expired"] == 1


def test_default_deadline_applies():
    _, a, b = _families()[0]
    clk = FakeClock()
    svc = SpgemmService(ServiceConfig(default_deadline=3.0), clock=clk)
    req = svc.submit(a, b)
    clk.advance(4.0)
    svc.drain()
    assert req.state == RequestState.EXPIRED


def test_budget_backpressure_serializes_waves():
    """A budget that fits ~one request at a time still drains everything —
    non-fitting batch mates simply stay queued (backpressure), they are
    never shed or failed."""
    _, a, b = _families()[0]
    probe = SpgemmService()
    r = probe.submit(a, b)
    probe.drain()
    one = r.estimate.total_bytes
    svc = SpgemmService(ServiceConfig(device_budget_bytes=int(one * 1.5),
                                      max_batch=8))
    reqs = [svc.submit(a, b) for _ in range(4)]
    svc.drain()
    assert all(r.state == RequestState.DONE for r in reqs)
    st = svc.stats()
    assert st["waves"] == 4, "budget must force one-request waves"
    assert st["queue"]["shed"] == 0 and st["terminal"]["FAILED"] == 0


def test_over_budget_request_fails_typed():
    _, a, b = _families()[0]
    svc = SpgemmService(ServiceConfig(device_budget_bytes=4096))
    req = svc.submit(a, b)
    svc.drain()
    assert req.state == RequestState.FAILED
    assert isinstance(req.error, AdmissionRejectedError)
    assert req.error.context["reason"] == "over_budget"
    assert req.error.context["observed"] > req.error.context["planned"]


# --------------------------------------------------------------------------- #
# capacity exhaustion → requeue once at escalated policy
# --------------------------------------------------------------------------- #
def test_capacity_exhausted_requeues_once_then_degrades():
    _, a, b = _families()[1]                    # power-law: starvation bites
    svc = SpgemmService(ServiceConfig(
        retry_policy=plan_mod.RetryPolicy(rounds=0, exact_fallback=False,
                                          on_exhausted="raise"),
        # no ladder on the retry either: recovery must come from the exact
        # symbolic fallback, which lands in the degradation ledger
        escalated_policy=plan_mod.RetryPolicy(rounds=0, exact_fallback=True,
                                              on_exhausted="raise")))
    req = svc.submit(a, b)
    with faults.inject(capacity_scale=0.1):
        svc.drain()
    assert req.attempts == 1
    assert svc.stats()["requeues"] == 1
    assert req.state == RequestState.DEGRADED, \
        "escalated retry (exact fallback) must recover the request"
    assert req.stats["degradations"], "degradation ledger must be attached"
    assert "first_error" in req.stats
    _assert_bitwise(req, a, b)


def test_capacity_exhausted_twice_fails_typed():
    """Both the base AND escalated policies denied recovery → the request
    fails typed after exactly one requeue, never loops."""
    _, a, b = _families()[1]
    hard = plan_mod.RetryPolicy(rounds=0, exact_fallback=False,
                                on_exhausted="raise")
    svc = SpgemmService(ServiceConfig(retry_policy=hard,
                                      escalated_policy=hard))
    req = svc.submit(a, b)
    with faults.inject(capacity_scale=0.05):
        svc.drain()
    assert req.state == RequestState.FAILED
    assert isinstance(req.error, CapacityExhaustedError)
    assert req.attempts == 1


# --------------------------------------------------------------------------- #
# circuit breaker
# --------------------------------------------------------------------------- #
def test_breaker_opens_after_consecutive_failures_then_recovers():
    _, a, b = _families()[0]
    clk = FakeClock()
    svc = SpgemmService(ServiceConfig(max_batch=1, breaker_threshold=2,
                                      breaker_cooldown=10.0), clock=clk)
    # two waves, each with its own armed executor fault → 2 consecutive
    # ShardFailureErrors on the same template's breaker
    failed = []
    for _ in range(2):
        failed.append(svc.submit(a, b))
        with faults.inject(fail_executor={"unit": "local"}):
            svc.step()
    assert all(r.state == RequestState.FAILED for r in failed)
    assert all(isinstance(r.error, ShardFailureError) for r in failed)
    assert svc.stats()["breakers"] == [
        dict(state="open", failures=2, trips=1)]

    # breaker open → next request fails FAST with the cause chained
    fast = svc.submit(a, b)
    svc.step()
    assert fast.state == RequestState.FAILED
    assert isinstance(fast.error, AdmissionRejectedError)
    assert fast.error.context["reason"] == "circuit_open"
    assert isinstance(fast.error.__cause__, ShardFailureError)

    # cooldown elapses → HALF_OPEN probe succeeds → breaker closes
    clk.advance(11.0)
    probe = svc.submit(a, b)
    svc.step()
    assert probe.state == RequestState.DONE
    assert svc.stats()["breakers"] == [
        dict(state="closed", failures=0, trips=1)]
    after = svc.submit(a, b)
    svc.step()
    assert after.state == RequestState.DONE


def test_half_open_probe_failure_reopens():
    clk = FakeClock()
    br = CircuitBreaker(threshold=1, cooldown=5.0)
    br.record_failure(clk(), ShardFailureError("x"))
    assert br.state == CircuitBreaker.OPEN and not br.allow(clk())
    clk.advance(6.0)
    assert br.allow(clk()) and br.state == CircuitBreaker.HALF_OPEN
    br.record_failure(clk(), ShardFailureError("y"))
    assert br.state == CircuitBreaker.OPEN and br.trips == 2


def test_breaker_isolation_across_templates():
    """One family's dying executor must not reject another family's
    traffic: breakers are per-template."""
    fams = _families()
    a0, b0 = fams[0][1], fams[0][2]
    a4, b4 = fams[4][1], fams[4][2]
    svc = SpgemmService(ServiceConfig(max_batch=1, breaker_threshold=1))
    dead = svc.submit(a0, b0)
    with faults.inject(fail_executor={"unit": "local"}):
        svc.step()
    assert dead.state == RequestState.FAILED
    other = svc.submit(a4, b4)
    svc.drain()
    assert other.state == RequestState.DONE
    states = {b["state"] for b in svc.stats()["breakers"]}
    assert states == {"open", "closed"}


# --------------------------------------------------------------------------- #
# chaos soak: all five fault classes through the full service loop
# --------------------------------------------------------------------------- #
@pytest.mark.slow
def test_chaos_soak_all_faults_terminate_typed_or_bitwise():
    """≥200 mixed-family requests, waves alternating through every fault
    class (capacity starvation, sketch corruption, gather starvation,
    executor failure, malformed operand).  EVERY request must reach a
    terminal state with a bitwise-correct result or a typed error; the
    queue must fully drain; and after the chaos, repeat no-fault traffic
    must add zero retraces."""
    fams = _families()
    svc = SpgemmService(ServiceConfig(queue_capacity=256, max_batch=4,
                                      breaker_threshold=3,
                                      breaker_cooldown=0.0))
    panel_svc = SpgemmService(ServiceConfig(queue_capacity=64, n_panels=2))
    refs: dict = {}

    def check(req, a, b):
        assert req.done, f"request {req.id} not terminal: {req.state}"
        if req.error is not None:
            assert isinstance(req.error, SpgemmError), \
                f"untyped error {type(req.error).__name__}"
            return
        key = id(a), id(b)
        if key not in refs:
            refs[key] = _reference(req.plan, a, b)
        ca = refs[key]
        np.testing.assert_array_equal(req.result.rpt, ca.rpt)
        np.testing.assert_array_equal(req.result.col, ca.col)
        np.testing.assert_allclose(req.result.val, ca.val,
                                   rtol=1e-5, atol=1e-5)

    waves = [
        dict(capacity_scale=0.2),
        dict(sketch_scale=0.05),
        dict(fail_executor={"unit": "local"}),
        dict(capacity_scale=0.3, sketch_scale=0.5),   # composed
        None,                                         # no-fault control
    ]
    submitted = 0
    nan_a = _nan_matrix()
    for round_i in range(8):
        batch = []
        for fam_i, (_, a, b) in enumerate(fams):
            for _ in range(5):                  # copies batch per template
                req = svc.submit(a, b)
                batch.append((req, a, b))
                submitted += 1
        # a malformed operand rides every round (fault class 5); it must be
        # contained at the front door without touching the queue
        bad = svc.submit(nan_a, nan_a)
        submitted += 1
        assert bad.state == RequestState.FAILED
        assert isinstance(bad.error, OperandValidationError)
        fault = waves[round_i % len(waves)]
        if fault is None:
            svc.drain()
        else:
            with faults.inject(seed=round_i, **fault):
                svc.drain()
        assert not faults.armed(), "fault context leaked past the wave"
        for req, a, b in batch:
            check(req, a, b)

    # gather starvation needs a panel plan: dedicated service, same contract
    for round_i in range(2):
        batch = [(panel_svc.submit(a, b), a, b)
                 for _, a, b in fams for _ in range(2)]
        submitted += len(batch)
        with faults.inject(gather_scale=0.25, seed=round_i):
            panel_svc.drain()
        for req, a, b in batch:
            assert req.done
            if req.error is not None:
                assert isinstance(req.error, SpgemmError)
            else:
                np.testing.assert_allclose(
                    req.result.to_dense(), spgemm_dense_oracle(a, b),
                    rtol=1e-4, atol=1e-4)

    assert submitted >= 200, f"soak too small: {submitted}"
    for s in (svc, panel_svc):
        st = s.stats()
        assert st["queue"]["depth"] == 0, "queue must drain"
        assert st["in_flight"] == 0, "every request must be terminal"

    # steady state after the storm: repeat templates retrace NOTHING
    for _, a, b in fams:
        svc.submit(a, b)
    svc.drain()
    traces = svc.stats()["plan_cache"]["traces"]
    post = [svc.submit(a, b) for _, a, b in fams for _ in range(2)]
    svc.drain()
    assert svc.stats()["plan_cache"]["traces"] == traces, \
        "post-chaos repeat traffic must add zero retraces"
    assert all(r.state == RequestState.DONE for r in post)
