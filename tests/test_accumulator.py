"""Hybrid accumulator backend: bitmask/dense-SPA vs sort/ESC routes.

The equivalence contract (DESIGN.md §5): symbolic ``z*``/``f*`` are
bitwise-equal across routes (distinct counts are order-invariant); numeric
``col``/``row_nnz``/``overflow`` are identical with ``val`` to float
tolerance (accumulation order differs).  Routing is a plan-time decision:
auto plans must never put a bucket on SPA when its dense column tile would
bust the VMEM lane budget.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal CI image — deterministic tests must still run
    from hypothesis_shim import given, settings, st

from repro.sparse import random as sprand
from repro.core import binning, csr, predictor, spgemm
from repro.core.flop import flop_per_row
from repro.kernels import ops, ref


def _families():
    """One small matrix pair per suite family (er/pl/rmat/band/fem)."""
    return [
        ("er", sprand.erdos_renyi(400, 400, 4, seed=31),
         sprand.erdos_renyi(400, 400, 3, seed=32)),
        ("pl", sprand.power_law(500, 500, 5, 1.5, seed=33),
         sprand.power_law(500, 500, 4, 1.6, seed=34)),
        ("rmat", sprand.rmat(400, 400, 2400, seed=35),
         sprand.rmat(400, 400, 2000, seed=36)),
        ("band", sprand.banded(500, 500, 10, 14, seed=37),
         sprand.banded(500, 500, 8, 12, seed=38)),
        ("fem", sprand.banded(300, 300, 24, 16, seed=39),
         sprand.banded(300, 300, 20, 14, seed=40)),
    ]


_IDS = [f[0] for f in _families()]


# --------------------------------------------------------------------------- #
# symbolic: dense/bitmask distinct == sorted distinct (bitwise)
# --------------------------------------------------------------------------- #
def test_count_distinct_dense_equals_sorted():
    for _, a, b in _families():
        ad, bd = csr.to_device(a), csr.to_device(b)
        mda, mdb = int(a.row_nnz.max()), int(b.row_nnz.max())
        rows = predictor.draw_sample_rows(jax.random.PRNGKey(0), a.nrows, 50)
        cols, _ = predictor.gather_sampled_products(ad, bd, rows, mda, mdb)
        np.testing.assert_array_equal(
            np.asarray(predictor.count_distinct_sorted(cols)),
            np.asarray(predictor.count_distinct_dense(cols, b.ncols)))


@pytest.mark.parametrize("samples,block", [(8, 8), (37, 8), (5, 16)])
def test_bitmask_kernel_sweep(samples, block):
    a = sprand.banded(200, 200, 8, 12, seed=3)
    b = sprand.erdos_renyi(200, 160, 5, seed=4)
    ad, bd = csr.to_device(a), csr.to_device(b)
    mda, mdb = int(a.row_nnz.max()), int(b.row_nnz.max())
    rows = predictor.draw_sample_rows(jax.random.PRNGKey(samples), 200, samples)
    zk, fk = ops.bitmask_symbolic(ad, bd, rows, mda, mdb, block_samples=block)
    zr, fr = ref.bitmask_symbolic_ref(ad, bd, rows, mda, mdb)
    zs, fs = ref.sampled_symbolic_ref(ad, bd, rows, mda, mdb)
    assert int(zk) == int(zr) == int(zs)
    assert int(fk) == int(fr) == int(fs)


def test_fused_bitmask_matches_fused_sort():
    _, a, b = _families()[3]
    ad, bd = csr.to_device(a), csr.to_device(b)
    mda, mdb = int(a.row_nnz.max()), int(b.row_nnz.max())
    rows = predictor.draw_sample_rows(jax.random.PRNGKey(7), a.nrows, 21)
    ze, fe, fle = ops.fused_flop_symbolic(ad, bd, rows, mda, mdb)
    zs, fs, fls = ops.fused_flop_symbolic_routed(
        ad, bd, rows, max_deg_a=mda, max_deg_b=mdb, route=binning.ROUTE_SPA)
    assert int(ze) == int(zs) and int(fe) == int(fs)
    np.testing.assert_array_equal(np.asarray(fle), np.asarray(fls))


# --------------------------------------------------------------------------- #
# numeric: dense-SPA kernel / jnp path == ESC (col/nnz/overflow exact)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("cap,tile", [(4, 64), (16, 64), (16, 256), (64, 128)])
def test_spa_numeric_kernel_sweep(cap, tile):
    """Includes tiled runs (tile < next_pow2(ncols)) and overflow caps."""
    a = sprand.banded(150, 150, 12, 6, seed=9)   # heavy collisions
    ad = csr.to_device(a)
    mda = int(a.row_nnz.max())
    rows = jnp.arange(150, dtype=jnp.int32)
    ck, vk, nk, ofk = ops.spgemm_numeric_spa(
        ad, ad, rows, max_deg_a=mda, max_deg_b=mda, row_capacity=cap,
        tile_n=tile, block_rows=8)
    cr_, vr_, nr_, ofr = ref.spgemm_numeric_ref(ad, ad, rows, mda, mda, cap)
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr_))
    np.testing.assert_allclose(np.asarray(vk), np.asarray(vr_), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(nk), np.asarray(nr_))
    assert int(ofk) == int(ofr)


def test_spa_jnp_path_matches_esc():
    for _, a, b in _families()[:3]:
        ad, bd = csr.to_device(a), csr.to_device(b)
        mda, mdb = int(a.row_nnz.max()), int(b.row_nnz.max())
        rows = jnp.asarray(np.arange(0, a.nrows, 3, dtype=np.int32))
        oe = spgemm.spgemm_rows(ad, bd, rows, row_capacity=16, max_deg_a=mda,
                                max_deg_b=mdb, block_rows=32)
        os_ = spgemm.spgemm_rows_spa(ad, bd, rows, row_capacity=16,
                                     max_deg_a=mda, max_deg_b=mdb,
                                     block_rows=32)
        np.testing.assert_array_equal(np.asarray(oe.col), np.asarray(os_.col))
        np.testing.assert_allclose(np.asarray(oe.val), np.asarray(os_.val),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(oe.row_nnz),
                                      np.asarray(os_.row_nnz))
        assert int(oe.overflow) == int(os_.overflow)


# --------------------------------------------------------------------------- #
# routing: forced esc/spa agree on every suite family (satellite contract)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name,a,b", _families(), ids=_IDS)
def test_forced_routes_agree_symbolic(name, a, b):
    ad, bd = csr.to_device(a), csr.to_device(b)
    rows = predictor.draw_sample_rows(jax.random.PRNGKey(1), a.nrows, 40)
    preds = {}
    for route in ("esc", "spa", "auto"):
        plan = binning.build_plan(a, b, route=route)
        preds[route] = predictor.proposed_predict_binned(ad, bd, rows, plan)
    for route in ("spa", "auto"):
        assert int(preds["esc"].sampled_nnz) == int(preds[route].sampled_nnz)
        assert int(preds["esc"].sampled_flop) == int(preds[route].sampled_flop)
        assert float(preds["esc"].nnz_total) == float(preds[route].nnz_total)
        np.testing.assert_array_equal(np.asarray(preds["esc"].structure),
                                      np.asarray(preds[route].structure))


@pytest.mark.parametrize("name,a,b", _families(), ids=_IDS)
def test_forced_routes_agree_numeric(name, a, b):
    ad, bd = csr.to_device(a), csr.to_device(b)
    floprc, _ = flop_per_row(ad, bd)
    rows = predictor.draw_sample_rows(jax.random.PRNGKey(2), a.nrows, 40)
    plan_e = binning.build_plan(a, b, route="esc")
    pred = predictor.proposed_predict_binned(ad, bd, rows, plan_e)
    alloc = predictor.AllocationPlan.from_prediction(
        np.asarray(pred.structure), np.asarray(floprc), safety=1.3)
    outs = {route: spgemm.spgemm_binned(
                ad, bd, binning.build_plan(a, b, route=route),
                alloc=alloc.row_capacity)
            for route in ("esc", "spa", "auto")}
    for route in ("spa", "auto"):
        np.testing.assert_array_equal(np.asarray(outs["esc"].col),
                                      np.asarray(outs[route].col))
        np.testing.assert_allclose(np.asarray(outs["esc"].val),
                                   np.asarray(outs[route].val),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(outs["esc"].row_nnz),
                                      np.asarray(outs[route].row_nnz))
        assert int(outs["esc"].overflow) == int(outs[route].overflow)


def test_forced_routes_agree_kernel_path():
    """Kernel (Pallas) dispatch: routed numeric + symbolic agree too."""
    _, a, b = _families()[3]
    ad, bd = csr.to_device(a), csr.to_device(b)
    rows = predictor.draw_sample_rows(jax.random.PRNGKey(4), a.nrows, 24)
    plans = {r: binning.build_plan(a, b, route=r) for r in ("esc", "spa")}
    pe = predictor.proposed_predict_binned(ad, bd, rows, plans["esc"],
                                           use_kernel=True)
    ps = predictor.proposed_predict_binned(ad, bd, rows, plans["spa"],
                                           use_kernel=True)
    assert int(pe.sampled_nnz) == int(ps.sampled_nnz)
    oe = spgemm.spgemm_binned(ad, bd, plans["esc"], alloc=24, use_kernel=True)
    os_ = spgemm.spgemm_binned(ad, bd, plans["spa"], alloc=24, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(oe.col), np.asarray(os_.col))
    np.testing.assert_allclose(np.asarray(oe.val), np.asarray(os_.val),
                               rtol=1e-5, atol=1e-5)
    assert int(oe.overflow) == int(os_.overflow)


# --------------------------------------------------------------------------- #
# routing: the VMEM-budget property + cost-model direction
# --------------------------------------------------------------------------- #
@given(st.integers(0, 10_000), st.integers(8, 4096), st.integers(10, 18))
@settings(max_examples=25, deadline=None)
def test_auto_plan_spa_fits_lane_budget(seed, ncols, budget_exp):
    """build_plan(route="auto") must never pick SPA when the dense column
    tile would exceed the VMEM lane budget: every SPA bucket satisfies
    block_rows·tile_n ≤ budget, covers the column space in ONE tile, and
    keeps ≥ spa_min_block_rows rows per block."""
    budget = 1 << budget_exp
    rng = np.random.default_rng(seed)
    a = sprand.erdos_renyi(64, ncols, int(rng.integers(1, 9)), seed=seed)
    b = sprand.erdos_renyi(ncols, ncols, int(rng.integers(1, 9)),
                           seed=seed + 1)
    plan = binning.build_plan(a, b, lane_budget=budget)
    for bk in plan.buckets:
        if bk.route == binning.ROUTE_SPA:
            assert bk.n_tiles == 1
            assert bk.tile_n >= binning.ceil_pow2(ncols) or \
                bk.tile_n * bk.n_tiles >= ncols
            assert bk.block_rows * bk.tile_n <= budget
            assert budget // bk.tile_n >= binning.DEFAULT_SPA_MIN_BLOCK_ROWS
        else:
            assert bk.tile_n == 0 and bk.n_tiles == 0


@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_forced_spa_tiles_cover_columns(seed):
    """Forced SPA always tiles instead of being rejected — tiles cover the
    pow2-padded column space and each tile block fits the budget."""
    rng = np.random.default_rng(seed)
    ncols = int(rng.integers(8, 3000))
    budget = 1 << int(rng.integers(8, 16))
    a = sprand.erdos_renyi(48, ncols, 3, seed=seed)
    b = sprand.erdos_renyi(ncols, ncols, 3, seed=seed + 1)
    plan = binning.build_plan(a, b, route="spa", lane_budget=budget)
    for bk in plan.buckets:
        assert bk.route == binning.ROUTE_SPA
        assert bk.tile_n * bk.n_tiles >= ncols
        assert bk.tile_n % binning.SPA_MIN_TILE == 0 or \
            bk.tile_n == binning.ceil_pow2(ncols)
        assert bk.block_rows * bk.tile_n <= max(budget, bk.tile_n)


def test_cost_model_routes_expected_regimes():
    """The regimes the router exists to separate (DESIGN.md §5): banded/FEM
    (wide buffers, compact columns) → SPA; low-degree ER and wide power-law
    column spaces → ESC."""
    # banded 2000-col: w≈150, sort pays ~64 stages/lane → SPA
    band = sprand.banded(2000, 2000, 12, 16, seed=13)
    assert binning.build_plan(band, band).route_rows()["esc"] == 0
    # power-law 3000-col: tile would leave <64 rows/block → all ESC
    pl = sprand.power_law(3000, 3000, 5, 1.5, seed=11)
    plb = sprand.power_law(3000, 3000, 4, 1.6, seed=12)
    assert binning.build_plan(pl, plb).route_rows()["spa"] == 0
    # tiny-width buckets: sorting a 4-lane buffer beats touching even a
    # narrow 128-lane tile — ESC; mid-width with narrow extent flips to SPA;
    # the same mid-width against a full-span extent stays ESC
    assert binning.choose_route(2, 2, 2000, 64)[0] == binning.ROUTE_ESC
    assert binning.choose_route(12, 12, 2000, 64)[0] == binning.ROUTE_SPA
    assert binning.choose_route(12, 12, 2000)[0] == binning.ROUTE_ESC
    # low-degree ER on a wide B keeps its narrow buckets on ESC
    er = sprand.erdos_renyi(2000, 2000, 3, seed=25)
    plan = binning.build_plan(er, er)
    narrow = [bk for bk in plan.buckets if bk.width <= 16]
    assert narrow and all(bk.route == binning.ROUTE_ESC for bk in narrow)


def test_signature_includes_route():
    """Route and tile are compile-cache keys: forced esc/spa plans of the
    same matrix must NOT share signatures (different programs)."""
    _, a, b = _families()[3]
    pe = binning.build_plan(a, b, route="esc")
    ps = binning.build_plan(a, b, route="spa")
    assert set(pe.signatures()).isdisjoint(ps.signatures())
    assert all(len(s) == 6 for s in pe.signatures())
