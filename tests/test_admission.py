"""Property suite for the serving admission cost model (DESIGN.md §10).

Two contracts make :mod:`repro.serve.admission` safe to admit against:

* **monotone** — scaling predicted per-row structure or the per-row FLOP
  bound UP never decreases the estimate (an admission controller that
  prices bigger work cheaper admits its way into OOM);
* **upper bound** — ``capacity_bytes`` dominates the bytes the planner
  actually allocates for output buffers, on every suite family, with and
  without ``pop_quant`` / templates / panels.

Plus the budget-ledger pins (reserve/release/fits) and the service-side
``estimate_cost`` round trip.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from hypothesis_shim import given, settings, st

from repro.core import plan as plan_mod
from repro.core.errors import AdmissionRejectedError
from repro.serve import admission
from repro.sparse import random as sprand


import jax


@pytest.fixture(scope="module", autouse=True)
def _release_executables():
    """Planning traces predictor/symbolic executors per family×variant;
    drop them from jax's global caches after the module so a long
    single-process suite run doesn't accumulate native compiler state."""
    yield
    jax.clear_caches()


def _families():
    return [
        ("er", sprand.erdos_renyi(250, 250, 4, seed=25),
         sprand.erdos_renyi(250, 250, 3, seed=26)),
        ("pl", sprand.power_law(300, 300, 5, 1.5, seed=21),
         sprand.power_law(300, 300, 4, 1.6, seed=22)),
        ("rmat", sprand.rmat(250, 250, 1250, seed=31),
         sprand.rmat(250, 250, 1000, seed=32)),
        ("band", sprand.banded(250, 250, 10, 14, seed=23),
         sprand.banded(250, 250, 8, 12, seed=24)),
        ("fem", sprand.banded(160, 160, 40, 30, seed=51),
         sprand.banded(160, 160, 32, 28, seed=52)),
    ]


def _estimate(structure, flopr, *, safety=1.3, n_panels=0):
    return admission.estimate(
        len(structure), np.asarray(structure, dtype=np.float64),
        np.asarray(flopr, dtype=np.float64), 2.0,
        nnz_a=64, nnz_b=64, nrows_b=64, safety=safety, n_panels=n_panels)


# --------------------------------------------------------------------------- #
# monotonicity: bigger predicted work never prices cheaper
# --------------------------------------------------------------------------- #
@settings(max_examples=60)
@given(st.lists(st.integers(0, 512), min_size=1, max_size=40),
       st.integers(1, 16), st.integers(1, 8))
def test_estimate_monotone_in_structure(raw, num, den):
    """Scaling every predicted row count by a factor >= 1 never decreases
    any byte/second field of the estimate."""
    structure = [x / 8.0 for x in raw]
    flopr = [4.0 * x + 8.0 for x in structure]   # FLOP bound stays above
    scale = 1.0 + num / den
    lo = _estimate(structure, flopr)
    hi = _estimate([s * scale for s in structure],
                   [f * scale for f in flopr])
    assert hi.capacity_bytes >= lo.capacity_bytes
    assert hi.total_bytes >= lo.total_bytes
    assert hi.est_seconds >= lo.est_seconds


@settings(max_examples=60)
@given(st.lists(st.integers(0, 512), min_size=1, max_size=40),
       st.integers(1, 16))
def test_estimate_monotone_in_flopr(raw, bump):
    """Raising only the per-row FLOP upper bound (structure fixed) never
    decreases the estimate — the min(ceil(s*safety), flopr) slot rule can
    only relax upward."""
    structure = [x / 8.0 for x in raw]
    flopr = [x / 2.0 for x in raw]               # sometimes BELOW structure
    lo = _estimate(structure, flopr)
    hi = _estimate(structure, [f + float(bump) for f in flopr])
    assert hi.capacity_bytes >= lo.capacity_bytes
    assert hi.total_bytes >= lo.total_bytes
    assert hi.est_seconds >= lo.est_seconds


@settings(max_examples=30)
@given(st.lists(st.integers(0, 256), min_size=1, max_size=24),
       st.integers(1, 4))
def test_estimate_monotone_in_panels(raw, panels):
    """More panels replicate per-panel buffers: the price never drops."""
    structure = [x / 4.0 for x in raw]
    flopr = [2.0 * x + 4.0 for x in structure]
    lo = _estimate(structure, flopr, n_panels=0)
    hi = _estimate(structure, flopr, n_panels=panels + 1)
    assert hi.capacity_bytes >= lo.capacity_bytes
    assert hi.total_bytes >= lo.total_bytes


# --------------------------------------------------------------------------- #
# upper bound: the formula dominates what the planner actually allocates
# --------------------------------------------------------------------------- #
PLAN_VARIANTS = [
    ("plain", {}),
    ("pop_quant", dict(pop_quant=True)),
    ("panels", dict(n_panels=2)),
]


@pytest.mark.parametrize("fam,a,b", _families(),
                         ids=[f[0] for f in _families()])
@pytest.mark.parametrize("variant,pkw", PLAN_VARIANTS,
                         ids=[v[0] for v in PLAN_VARIANTS])
def test_formula_bounds_planned_capacity(fam, a, b, variant, pkw):
    """The pure-formula estimate (no plan introspection) upper-bounds the
    planner's exactly-allocated output bytes for a FRESH plan on every
    suite family and plan shape."""
    plan = plan_mod.plan_spgemm(a, b, **pkw)
    est = admission.estimate(
        plan.shape_a[0], plan.structure, plan.flopr,
        plan.compression_ratio, nnz_a=plan.cap_a, nnz_b=plan.cap_b,
        nrows_b=plan.shape_b[0], safety=plan.safety, n_panels=plan.n_panels)
    actual = admission.planned_bytes(plan)
    assert est.capacity_bytes >= actual, (
        f"{fam}/{variant}: estimate {est.capacity_bytes} under-prices "
        f"planned {actual}")
    # and the service-side wrapper can only tighten upward
    assert admission.estimate_cost(plan).capacity_bytes >= actual


def test_estimate_cost_covers_template_growth():
    """A template grown by a LATER family member inflates earlier members'
    replanned capacities; estimate_cost must still dominate via the
    planned-bytes max."""
    reg = plan_mod.TemplateRegistry()
    fams = _families()
    small_a, small_b = fams[1][1], fams[1][2]
    plan_mod.plan_spgemm(small_a, small_b, template="auto", registry=reg)
    # a denser same-shape sibling grows the family template
    big_a = sprand.power_law(300, 300, 9, 1.3, seed=91)
    big_b = sprand.power_law(300, 300, 8, 1.4, seed=92)
    plan_mod.plan_spgemm(big_a, big_b, template="auto", registry=reg)
    replanned = plan_mod.plan_spgemm(small_a, small_b, template="auto",
                                     registry=reg)
    est = admission.estimate_cost(replanned)
    assert est.capacity_bytes >= admission.planned_bytes(replanned)
    assert est.total_bytes == est.capacity_bytes + est.operand_bytes


# --------------------------------------------------------------------------- #
# budget ledger
# --------------------------------------------------------------------------- #
def _flat_estimate(total_bytes: int) -> admission.CostEstimate:
    return admission.CostEstimate(
        flop=0, predicted_nnz=0.0, compression_ratio=1.0, operand_bytes=0,
        capacity_bytes=total_bytes, total_bytes=total_bytes, est_seconds=0.0)


def test_budget_reserve_release_round_trip():
    budget = admission.MemoryBudget(1000)
    est = _flat_estimate(400)
    assert budget.fits_ever(est) and budget.fits_now(est)
    budget.reserve(est)
    budget.reserve(est)
    assert budget.remaining == 200
    assert not budget.fits_now(est)          # backpressure point
    assert budget.fits_ever(est)             # ...but not a permanent reject
    with pytest.raises(AdmissionRejectedError) as ei:
        budget.reserve(est)
    assert ei.value.context["reason"] == "budget"
    budget.release(est)
    budget.release(est)
    assert budget.remaining == 1000
    budget.release(est)                      # over-release clamps at zero
    assert budget.reserved == 0


def test_budget_fits_ever_rejects_impossible():
    budget = admission.MemoryBudget(1000)
    assert not budget.fits_ever(_flat_estimate(1001))
    with pytest.raises(Exception):
        admission.MemoryBudget(0)
