"""Per-kernel allclose vs the pure-jnp oracles (interpret=True), with
shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sparse import random as sprand
from repro.core import csr, predictor
from repro.kernels import ops, ref
from repro.kernels.sortnet import (bitonic_sort, bitonic_sort_pairs,
                                   segmented_run_sums, next_pow2)


# --------------------------------------------------------------------------- #
# sortnet
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("n", [2, 8, 64, 256])
@pytest.mark.parametrize("rows", [1, 5])
def test_bitonic_matches_npsort(n, rows):
    x = jnp.asarray(np.random.default_rng(n + rows).integers(
        0, 1000, size=(rows, n)).astype(np.int32))
    np.testing.assert_array_equal(np.sort(np.asarray(x), -1),
                                  np.asarray(bitonic_sort(x)))


def test_bitonic_pairs_preserve_value_multiset():
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.integers(0, 9, size=(3, 32)).astype(np.int32))
    v = jnp.asarray(rng.random((3, 32)).astype(np.float32))
    ks, vs = bitonic_sort_pairs(k, v)
    for r in range(3):
        for key in np.unique(np.asarray(k[r])):
            got = np.asarray(vs[r])[np.asarray(ks[r]) == key].sum()
            want = np.asarray(v[r])[np.asarray(k[r]) == key].sum()
            assert abs(got - want) < 1e-5


def test_segmented_run_sums():
    k = jnp.asarray([[1, 1, 2, 2, 2, 7, 9, 9]], dtype=jnp.int32)
    v = jnp.asarray([[1., 2., 3., 4., 5., 6., 7., 8.]], dtype=jnp.float32)
    first, sums = segmented_run_sums(k, v, sentinel=jnp.int32(9))  # 9=sentinel
    f = np.asarray(first[0])
    s = np.asarray(sums[0])
    assert list(f) == [True, False, True, False, False, True, False, False]
    assert s[0] == 3.0 and s[2] == 12.0 and s[5] == 6.0


def test_next_pow2():
    assert [next_pow2(x) for x in (1, 2, 3, 5, 8, 9)] == [1, 2, 4, 8, 8, 16]


# --------------------------------------------------------------------------- #
# kernels vs refs: shape sweeps
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("m,n,da,db,block", [
    (100, 100, 4, 4, 32), (257, 180, 7, 3, 64), (64, 512, 12, 9, 16)])
def test_flop_kernel_sweep(m, n, da, db, block):
    a = sprand.erdos_renyi(m, n, da, seed=m)
    b = sprand.erdos_renyi(n, m, db, seed=n)
    ad, bd = csr.to_device(a), csr.to_device(b)
    mda = int(a.row_nnz.max())
    got = ops.flop_per_row(ad, bd, block_rows=block, max_deg_a=mda)
    want = ref.flop_per_row_ref(ad.rpt, ad.col, jnp.diff(bd.rpt))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("samples,block", [(8, 8), (37, 8), (5, 16)])
def test_symbolic_kernel_sweep(samples, block):
    a = sprand.banded(200, 200, 8, 12, seed=3)
    b = sprand.erdos_renyi(200, 160, 5, seed=4)
    ad, bd = csr.to_device(a), csr.to_device(b)
    mda, mdb = int(a.row_nnz.max()), int(b.row_nnz.max())
    rows = predictor.draw_sample_rows(jax.random.PRNGKey(samples), 200, samples)
    zk, fk = ops.sampled_symbolic(ad, bd, rows, mda, mdb, block_samples=block)
    zr, fr = ref.sampled_symbolic_ref(ad, bd, rows, mda, mdb)
    assert int(zk) == int(zr)
    assert int(fk) == int(fr)


def test_symbolic_kernel_feeds_predictor():
    """predictor(use_kernel=True) == predictor(use_kernel=False)."""
    a = sprand.banded(300, 300, 9, 11, seed=6)
    ad = csr.to_device(a)
    mda = int(a.row_nnz.max())
    rows = predictor.draw_sample_rows(jax.random.PRNGKey(0), 300, 16)
    p_ref = predictor.proposed_predict(ad, ad, rows, mda, mda, use_kernel=False)
    p_ker = predictor.proposed_predict(ad, ad, rows, mda, mda, use_kernel=True)
    assert float(p_ref.nnz_total) == pytest.approx(float(p_ker.nnz_total))


@pytest.mark.parametrize("cap", [16, 64])
def test_numeric_kernel_sweep(cap):
    a = sprand.erdos_renyi(150, 150, 6, seed=8)
    b = sprand.erdos_renyi(150, 120, 4, seed=9)
    ad, bd = csr.to_device(a), csr.to_device(b)
    mda, mdb = int(a.row_nnz.max()), int(b.row_nnz.max())
    rows = jnp.arange(150, dtype=jnp.int32)
    ck, vk, nk, ofk = ops.spgemm_numeric(ad, bd, rows, max_deg_a=mda,
                                         max_deg_b=mdb, row_capacity=cap,
                                         block_rows=8)
    cr_, vr_, nr_, ofr = ref.spgemm_numeric_ref(ad, bd, rows, mda, mdb, cap)
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr_))
    np.testing.assert_allclose(np.asarray(vk), np.asarray(vr_), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(nk), np.asarray(nr_))
    assert int(ofk) == int(ofr)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sq,sk,d,causal", [
    (128, 128, 64, True), (128, 256, 64, False), (256, 256, 32, True)])
def test_flash_attention_sweep(sq, sk, d, causal, dtype):
    rng = np.random.default_rng(sq + sk + d)
    q = jnp.asarray(rng.standard_normal((1, 4, sq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((1, 2, sk, d)), dtype)
    v = jnp.asarray(rng.standard_normal((1, 2, sk, d)), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)
