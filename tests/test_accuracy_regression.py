"""Paper-accuracy regression gate (Section VI, ISSUE 4).

Runs a deterministic 75-case subset of the 625-case suite — 3 cases per
ordered family pair, same per-case seeds as the full sweep — and pins the
proposed method's mean absolute relative error against the committed
baseline artifact (``artifacts/accuracy_subset_baseline.json``).  Any future
refactor of the predictor pipeline that degrades the paper's 1.56% / 8.12%
headline behaviour fails this gate in CI.  Regenerate the baseline (after an
*intentional* accuracy change only) with::

    PYTHONPATH=src python -m repro.core.experiment --subset-baseline

The full 625-case sweep stays behind ``-m slow``.
"""
import json
import os

import numpy as np
import pytest

from repro.core import experiment

BASELINE = os.path.abspath(experiment.SUBSET_BASELINE)


@pytest.fixture(scope="module")
def subset():
    return experiment.run_subset()


@pytest.fixture(scope="module")
def baseline():
    assert os.path.exists(BASELINE), (
        "committed baseline missing — run "
        "`python -m repro.core.experiment --subset-baseline`")
    with open(BASELINE) as f:
        return json.load(f)


def test_subset_is_deterministic_and_balanced():
    pairs = experiment.subset_pairs()
    assert len(pairs) == 75
    assert len(set(pairs)) == 75, "subset picks must be distinct"
    assert pairs == experiment.subset_pairs(), "subset must be deterministic"


def test_proposed_beats_reference(subset):
    """The paper's core claim on the subset: mean |e2| < mean |e1| (and the
    proposed method wins the majority of cases)."""
    agg = subset["aggregate"]
    assert agg["mean_abs_e2"] < agg["mean_abs_e1"]
    assert agg["proposed_better_frac"] > 0.5
    # eq. 5 identity holds to float precision on every case
    assert agg["max_eq5_resid"] < 1e-9


def test_proposed_error_below_pinned_threshold(subset, baseline):
    agg = subset["aggregate"]
    pin = baseline["pinned"]
    assert agg["mean_abs_e2"] <= pin["max_mean_abs_e2"], (
        "proposed-method accuracy regressed past the committed gate")
    assert agg["worst_abs_e2"] <= pin["max_worst_abs_e2"], (
        "proposed-method worst case regressed past the committed gate")


def test_per_case_errors_track_baseline(subset, baseline):
    """No single case may silently blow up even while the aggregate stays
    under the gate (the drift band absorbs RNG-stream changes across numpy
    versions — anything larger is a real regression)."""
    base = {(c["A"], c["B"]): c for c in baseline["cases"]}
    drift = baseline["pinned"]["max_case_abs_e2_drift"]
    assert len(subset["cases"]) == len(base)
    for c in subset["cases"]:
        b = base[(c["A"], c["B"])]
        assert abs(c["e2"] - b["e2"]) <= drift, (c["A"], c["B"], c["e2"],
                                                 b["e2"])
        # exact NNZ / FLOP are sampling-independent: bitwise stable
        assert c["nnz"] == b["nnz"] and c["flop"] == b["flop"]


@pytest.mark.slow
def test_full_625_sweep(tmp_path):
    """The complete Section VI reproduction (minutes; slow-marked)."""
    res = experiment.run_all(out_path=str(tmp_path / "accuracy_625.json"),
                             verbose=False)
    agg = res["aggregate"]
    assert agg["n_cases"] == 625
    assert agg["mean_abs_e2"] < agg["mean_abs_e1"]
    assert agg["mean_abs_e2"] < 0.05          # paper: 1.56%
    assert agg["proposed_better_frac"] > 0.6  # paper: 81.4%
    assert agg["max_eq5_resid"] < 1e-9
