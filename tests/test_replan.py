"""Overflow re-planning loop (core/plan.py, DESIGN.md §7): adversarial
under-allocation on every suite family.

``safety=0`` floors every bucket capacity at the 8-slot alignment minimum, so
the numeric phase overflows by construction; the armed retry loop must
converge, only the overflowing buckets may re-execute (trace-count pinned
through ``PlanCache``), and the spliced result must match an ample-capacity
``spgemm_binned`` run bitwise on ``row_nnz``/``col``.  The 4-device
shard_map variant runs in a subprocess (device-count env must precede jax
init), like ``tests/test_distributed.py``."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.sparse import random as sprand
from repro.sparse.formats import CSR, spgemm_dense_oracle
from repro.core import plan as plan_mod, spgemm

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _families():
    return [
        ("er", sprand.erdos_renyi(400, 400, 4, seed=25),
         sprand.erdos_renyi(400, 400, 3, seed=26)),
        ("pl", sprand.power_law(500, 500, 5, 1.5, seed=21),
         sprand.power_law(500, 500, 4, 1.6, seed=22)),
        ("rmat", sprand.rmat(400, 400, 2000, seed=31),
         sprand.rmat(400, 400, 1600, seed=32)),
        ("band", sprand.banded(400, 400, 10, 14, seed=23),
         sprand.banded(400, 400, 8, 12, seed=24)),
        ("fem", sprand.banded(300, 300, 40, 30, seed=51),
         sprand.banded(300, 300, 32, 28, seed=52)),
    ]


def _ample_reference(p, a, b):
    """Ample-capacity binned run on the same sample — the ground truth the
    retried result must match bitwise on row_nnz/col."""
    pa = plan_mod.plan_spgemm(a, b, safety=64.0, sample_rows=p.sample_rows)
    oa = spgemm.spgemm_binned(pa.to_device(a, "a"), pa.to_device(b, "b"),
                              pa.binning, alloc=pa.alloc)
    assert int(oa.overflow) == 0, "reference must not overflow"
    return pa, oa


@pytest.mark.parametrize("name,a,b",
                         _families(),
                         ids=[f[0] for f in _families()])
def test_replan_converges_and_matches_ample(name, a, b):
    cache = plan_mod.PlanCache()
    p = plan_mod.plan_spgemm(a, b, safety=0.0, retry_safety=1.5)
    caps_before = list(p.alloc.bucket_capacities)
    out = plan_mod.execute(p, a, b, cache=cache)

    pa, oa = _ample_reference(p, a, b)
    ref_nnz = np.asarray(oa.row_nnz)
    overflowed = {i for i, bk in enumerate(p.binning.buckets)
                  if int(ref_nnz[bk.rows].max()) > caps_before[i]}
    assert overflowed, f"{name}: safety=0 failed to force under-allocation"

    # converged: every dropped entry recovered through the bumped buckets
    assert p.retries >= 1
    assert int(out.overflow) == 0
    # ONLY the overflowing buckets re-executed...
    assert {e["bucket"] for e in p.retry_events} == overflowed
    # ...each through exactly one freshly-traced per-bucket executor
    assert cache.stats()["traces"] == 1 + len(p.retry_events)
    for e in p.retry_events:
        assert e["new_cap"] >= e["need"] > e["old_cap"]

    # bitwise contract vs the ample run
    np.testing.assert_array_equal(np.asarray(out.row_nnz), ref_nnz)
    c = plan_mod.reassemble(p, out)
    ca = plan_mod.reassemble(pa, oa)
    np.testing.assert_array_equal(c.rpt, ca.rpt)
    np.testing.assert_array_equal(c.col, ca.col)
    np.testing.assert_allclose(c.val, ca.val, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(c.to_dense(), spgemm_dense_oracle(a, b),
                               rtol=1e-4, atol=1e-4)

    # the plan's capacities were bumped in place: a second execute of the
    # SAME plan allocates right the first time (no retry rounds)
    out2 = plan_mod.execute(p, a, b, cache=cache)
    assert p.retries == 0 and int(out2.overflow) == 0


def test_no_overflow_fast_path_zero_retraces():
    """Armed retry + ample safety: the fast path costs one host readback of
    row_nnz and ZERO retraces — serving traffic never pays for the loop."""
    a = sprand.banded(300, 300, 8, 10, seed=3)
    cache = plan_mod.PlanCache()
    p = plan_mod.plan_spgemm(a, a, safety=2.0, retry_safety=1.5)
    out = plan_mod.execute(p, a, a, cache=cache)
    assert p.retries == 0 and not p.retry_events
    assert int(out.overflow) == 0
    t = cache.stats()["traces"]
    plan_mod.execute(p, a, a, cache=cache)
    assert cache.stats()["traces"] == t, "no-overflow fast path retraced"


def _hub_matrix(m=400, hub_deg=60):
    """Low-degree bulk + one hub row: only the hub's bucket under-allocates
    at the 8-slot floor (bulk rows never reference the hub row, so their
    output stays ≤ 3 nnz)."""
    rng = np.random.default_rng(7)
    r = np.arange(1, m)
    rows = np.repeat(r, 2)
    cols = np.stack([r, np.minimum(r + 1, m - 1)], axis=1).reshape(-1)
    hub_cols = rng.choice(np.arange(1, m), hub_deg, replace=False)
    rows = np.concatenate([np.zeros(hub_deg, np.int64), rows])
    cols = np.concatenate([hub_cols, cols])
    vals = rng.standard_normal(rows.size).astype(np.float32)
    return CSR.from_coo(rows, cols, vals, (m, m))


def test_only_hub_bucket_retries():
    """Partial overflow: the bulk buckets stay untouched (capacities AND
    executors), only the hub's bucket pays the retry."""
    a = _hub_matrix()
    cache = plan_mod.PlanCache()
    p = plan_mod.plan_spgemm(a, a, safety=0.0, retry_safety=1.5)
    hub_bucket = int(p.binning.row_bucket[0])
    out = plan_mod.execute(p, a, a, cache=cache)
    assert int(out.overflow) == 0
    assert {e["bucket"] for e in p.retry_events} == {hub_bucket}
    assert cache.stats()["traces"] == 1 + len(p.retry_events)
    caps = p.alloc.bucket_capacities
    for i, cap in enumerate(caps):
        if i != hub_bucket:
            assert cap == 8, "non-overflowing bucket capacity was bumped"
    c = plan_mod.reassemble(p, out)
    np.testing.assert_allclose(c.to_dense(), spgemm_dense_oracle(a, a),
                               rtol=1e-4, atol=1e-4)


def test_replan_with_kernel_route():
    a = sprand.banded(300, 300, 12, 10, seed=5)
    b = sprand.banded(300, 300, 8, 10, seed=6)
    p = plan_mod.plan_spgemm(a, b, safety=0.0, retry_safety=1.5,
                             use_kernel=True)
    out = plan_mod.execute(p, a, b, cache=plan_mod.PlanCache())
    assert p.retries >= 1 and int(out.overflow) == 0
    c = plan_mod.reassemble(p, out)
    np.testing.assert_allclose(c.to_dense(), spgemm_dense_oracle(a, b),
                               rtol=1e-4, atol=1e-4)


def test_replan_with_pop_quant():
    """Quantized plans retry too: padded bucket tables re-execute whole, pad
    rows stay masked out of the overflow count."""
    a = sprand.power_law(500, 500, 5, 1.5, seed=21)
    b = sprand.power_law(500, 500, 4, 1.6, seed=22)
    p = plan_mod.plan_spgemm(a, b, safety=0.0, retry_safety=1.5,
                             pop_quant=True)
    out = plan_mod.execute(p, a, b, cache=plan_mod.PlanCache())
    assert p.retries >= 1 and int(out.overflow) == 0
    c = plan_mod.reassemble(p, out)
    np.testing.assert_allclose(c.to_dense(), spgemm_dense_oracle(a, b),
                               rtol=1e-4, atol=1e-4)


def test_max_retries_zero_leaves_overflow_surfaced():
    """An armed loop with no budget must not silently truncate — overflow
    stays on the result and reassemble raises."""
    a = sprand.banded(200, 200, 10, 12, seed=9)
    p = plan_mod.plan_spgemm(a, a, safety=0.0, retry_safety=1.5,
                             max_retries=0)
    out = plan_mod.execute(p, a, a, cache=plan_mod.PlanCache())
    assert p.retries == 0
    assert int(out.overflow) > 0
    with pytest.raises(ValueError, match="overflow"):
        plan_mod.reassemble(p, out)


# --------------------------------------------------------------------------- #
# RetryPolicy escalation (DESIGN.md §9): rounds sweep + exact-symbolic
# fallback on every family
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("rounds", [0, 1, 2])
@pytest.mark.parametrize("name,a,b", _families(),
                         ids=[f[0] for f in _families()])
def test_retry_policy_rounds_sweep(name, a, b, rounds):
    """safety=0 under-allocation under the typed policy: whatever the
    ladder cannot close within its budget is closed by the exact-symbolic
    fallback — ``execute`` always converges, bitwise vs the ample run."""
    p = plan_mod.plan_spgemm(a, b, safety=0.0,
                             retry_policy=plan_mod.RetryPolicy(rounds=rounds))
    caps_before = list(p.alloc.bucket_capacities)
    out = plan_mod.execute(p, a, b, cache=plan_mod.PlanCache())

    pa, oa = _ample_reference(p, a, b)
    ref_nnz = np.asarray(oa.row_nnz)
    overflowed = {i for i, bk in enumerate(p.binning.buckets)
                  if bk.n_rows and int(ref_nnz[bk.rows].max()) > caps_before[i]}
    assert overflowed, f"{name}: safety=0 failed to force under-allocation"
    assert int(out.overflow) == 0

    if rounds == 0:
        # no ladder budget at all: EVERY starved bucket must appear in the
        # degradation ledger, each closed by one exact-symbolic execute
        assert p.retries == 0 and not p.retry_events
        assert {d["bucket"] for d in p.degradations} == overflowed
    else:
        # row_nnz is exact, so the ladder (floored at the observed need)
        # converges in one round — the fallback never fires
        assert p.retries == 1
        assert {e["bucket"] for e in p.retry_events} == overflowed
        assert not p.degradations
    for d in p.degradations:
        assert d["kind"] == "exact_symbolic"
        assert d["new_cap"] >= d["need"] > d["old_cap"]

    c = plan_mod.reassemble(p, out)
    ca = plan_mod.reassemble(pa, oa)
    np.testing.assert_array_equal(c.rpt, ca.rpt)
    np.testing.assert_array_equal(c.col, ca.col)
    np.testing.assert_allclose(c.val, ca.val, rtol=1e-5, atol=1e-5)


def test_retry_policy_ceiling_forces_fallback():
    """A max_capacity ceiling clamps the ladder; starved buckets above it
    must reach the exact fallback (which ignores the ceiling) instead of
    looping forever or surfacing overflow."""
    _, a, b = _families()[1]      # power-law: hub rows far above the floor
    p = plan_mod.plan_spgemm(
        a, b, safety=0.0,
        retry_policy=plan_mod.RetryPolicy(rounds=2, max_capacity=16))
    out = plan_mod.execute(p, a, b, cache=plan_mod.PlanCache())
    assert int(out.overflow) == 0
    assert p.degradations, "ceiling-clamped buckets must hit the fallback"
    assert all(e["new_cap"] <= 16 for e in p.retry_events), \
        "ladder bumped past the max_capacity ceiling"
    assert any(d["new_cap"] > 16 for d in p.degradations)
    c = plan_mod.reassemble(p, out)
    np.testing.assert_allclose(c.to_dense(), spgemm_dense_oracle(a, b),
                               rtol=1e-4, atol=1e-4)


def test_retry_policy_exhausted_raises_typed():
    """No rounds, no fallback, on_exhausted='raise': a typed
    CapacityExhaustedError naming the starved buckets, not silent overflow."""
    a = sprand.banded(200, 200, 10, 12, seed=9)
    p = plan_mod.plan_spgemm(
        a, a, safety=0.0,
        retry_policy=plan_mod.RetryPolicy(rounds=0, exact_fallback=False,
                                          on_exhausted="raise"))
    with pytest.raises(plan_mod.CapacityExhaustedError, match="exhausted") \
            as exc:
        plan_mod.execute(p, a, a, cache=plan_mod.PlanCache())
    assert exc.value.context["buckets"]
    assert exc.value.context["observed"] > 0


# --------------------------------------------------------------------------- #
# 4-device shard_map: the distributed retry loop (subprocess, like
# tests/test_distributed.py)
# --------------------------------------------------------------------------- #
REPLAN_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax

from repro.sparse import random as sprand
from repro.sparse.formats import CSR, spgemm_dense_oracle
from repro.core import plan as plan_mod, spgemm

def revalue(m, seed):
    rng = np.random.default_rng(seed)
    return CSR(rpt=m.rpt.copy(), col=m.col.copy(),
               val=rng.standard_normal(m.nnz).astype(np.float32),
               shape=m.shape)

mesh = jax.make_mesh((4,), ("data",))
fams = [
    ("er", sprand.erdos_renyi(400, 400, 4, seed=25),
     sprand.erdos_renyi(400, 400, 3, seed=26)),
    ("pl", sprand.power_law(500, 500, 5, 1.5, seed=21),
     sprand.power_law(500, 500, 4, 1.6, seed=22)),
    ("rmat", sprand.rmat(400, 400, 2000, seed=31),
     sprand.rmat(400, 400, 1600, seed=32)),
    ("band", sprand.banded(400, 400, 10, 14, seed=23),
     sprand.banded(400, 400, 8, 12, seed=24)),
    ("fem", sprand.banded(300, 300, 40, 30, seed=51),
     sprand.banded(300, 300, 32, 28, seed=52)),
]
out = {}
for fam, a, b in fams:
    cache = plan_mod.PlanCache()
    p = plan_mod.plan_spgemm(a, b, mesh=mesh, safety=0.0, retry_safety=1.5)
    caps_before = [t.capacity for t in p.shard_tables]
    res = plan_mod.execute(p, a, b, cache=cache)
    c = plan_mod.reassemble(p, res)

    # ample single-device binned reference on the same sample
    pa = plan_mod.plan_spgemm(a, b, safety=64.0, sample_rows=p.sample_rows)
    oa = spgemm.spgemm_binned(pa.to_device(a, "a"), pa.to_device(b, "b"),
                              pa.binning, alloc=pa.alloc)
    ca = plan_mod.reassemble(pa, oa)
    ref_nnz = np.asarray(oa.row_nnz)
    overflowed = sorted(
        i for i, bk in enumerate(p.binning.buckets)
        if int(ref_nnz[bk.rows].max()) > caps_before[i])

    # serving after the retry: same structure, new values — the bumped plan
    # re-keys onto its final capacities, so the pair pays fresh executors
    # ONCE and the retry loop never fires again for this structure
    a2, b2 = revalue(a, 91), revalue(b, 92)
    p2 = plan_mod.plan_spgemm(a2, b2, mesh=mesh, safety=0.0,
                              retry_safety=1.5)
    res2 = plan_mod.execute(p2, a2, b2, cache=cache)
    retraces2 = (cache.stats()["traces"]
                 - (1 + len(p.retry_events)))   # base + per-bucket retries

    out[fam] = dict(
        retries=p.retries,
        retried=sorted({e["bucket"] for e in p.retry_events}),
        overflowed=overflowed,
        traces=cache.stats()["traces"],
        events=len(p.retry_events),
        overflow=int(res.shard_overflow.sum()),
        overflow2=int(res2.shard_overflow.sum()),
        retraces2=retraces2,
        rpt_eq=bool((c.rpt == ca.rpt).all()),
        col_eq=bool((c.col == ca.col).all()),
        vdiff=float(np.abs(c.val - ca.val).max()),
        ref_err=float(np.abs(c.to_dense() - spgemm_dense_oracle(a, b)).max()),
    )
print(json.dumps(out))
"""


def _run(script: str, timeout: int = 900) -> dict:
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_replan_4dev_all_families():
    rec = _run(REPLAN_SCRIPT)
    for fam, r in rec.items():
        assert r["retries"] >= 1, (fam, r)
        assert r["overflow"] == 0, (fam, r)
        assert r["retried"] == r["overflowed"], (fam, r)
        assert r["traces"] == 1 + r["events"], (fam, r)
        assert r["rpt_eq"] and r["col_eq"], (fam, r)
        assert r["vdiff"] < 1e-4, (fam, r)
        assert r["ref_err"] < 1e-3, (fam, r)
        # serving pair through the armed loop: converged, zero NEW retraces
        assert r["overflow2"] == 0, (fam, r)
        assert r["retraces2"] == 0, (fam, r)
