"""Launch-layer specs: shape-cell table, skip rules, batch-axis divisibility,
decode structs, and the sharding rules."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import registry, get_config
from repro.launch import specs
from repro.models.sharding import make_rules, specs_from_schema, cache_spec_tree
from repro.models.transformer import build_schema
from repro.models.schema import abstract_params


def test_shapes_table():
    assert specs.SHAPES["train_4k"] == dict(kind="train", seq=4096, batch=256)
    assert specs.SHAPES["long_500k"]["seq"] == 524_288


def test_live_cells_count():
    archs = list(registry().keys())
    cells = specs.live_cells(archs)
    # 10 × (train, prefill, decode) + 2 × long_500k
    assert len(cells) == 32
    assert ("xlstm-125m", "long_500k") in cells
    assert ("qwen2.5-32b", "long_500k") not in cells


@pytest.mark.parametrize("arch", list(registry().keys()))
def test_batch_axes_divisible(arch):
    cfg = get_config(arch)
    sizes = {"pod": 2, "data": 16, "model": 16}
    for shape, sh in specs.SHAPES.items():
        if not specs.cell_is_live(arch, shape):
            continue
        for mp in (False, True):
            ax = specs._batch_axes(cfg, sh["batch"], mp)
            if ax is None:
                assert sh["batch"] < 16  # only the tiny batches
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            prod = 1
            for a in axes:
                prod *= sizes[a]
            assert sh["batch"] % prod == 0, (arch, shape, axes)


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "deepseek-v3-671b",
                                  "zamba2-7b", "whisper-small"])
def test_decode_structs_and_specs_align(arch):
    cfg = get_config(arch)
    tokens, cur_len, cache, enc = specs.decode_structs(cfg, "decode_32k")
    t_spec, l_spec, cache_specs, enc_spec = specs.decode_pspecs(
        cfg, "decode_32k", multi_pod=False)
    assert tokens.shape == (128, 1)
    # cache spec tree matches the cache structure
    assert (jax.tree_util.tree_structure(cache) ==
            jax.tree_util.tree_structure(
                cache_specs, is_leaf=lambda x: isinstance(x, P)))
    if cfg.is_encoder_decoder:
        assert enc is not None and enc_spec is not None


@pytest.mark.parametrize("arch", list(registry().keys()))
def test_param_specs_divide_shapes(arch):
    """Every sharded param dim must divide by its mesh axis size."""
    cfg = get_config(arch)
    sizes = {"pod": 2, "data": 16, "model": 16}
    schema = build_schema(cfg, mesh_model=16)
    rules = make_rules(cfg, mesh_model=16, multi_pod=True)
    pspecs = specs_from_schema(schema, rules)
    params = abstract_params(schema)
    flat_p = jax.tree_util.tree_leaves(params)
    flat_s = jax.tree_util.tree_leaves(
        pspecs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            n = 1
            for a in axes:
                n *= sizes[a]
            assert dim % n == 0, (arch, leaf.shape, spec)


def test_non_tp_rules_replicate_weights():
    cfg = get_config("xlstm-125m")
    rules = make_rules(cfg, mesh_model=16, multi_pod=False)
    assert rules["ff"] is None and rules["ssm_inner"] is None
    cfg2 = get_config("qwen2.5-32b")
    rules2 = make_rules(cfg2, mesh_model=16, multi_pod=False)
    assert rules2["ff"] == "model"
