"""Decode-with-cache must reproduce the full teacher-forced forward —
the strongest serving-correctness invariant, covering every cache family
(GQA, MLA latent+absorbed, Mamba2 state, mLSTM state, sLSTM state,
shared-attn hybrid, enc-dec cross attention)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import smoke_registry
from repro.models import transformer as T
from repro.models.schema import init_params

S = 12
B = 2

# capacity high enough that the MoE drops nothing in either path
CAP = 64

CASES = ["qwen2.5-32b", "deepseek-v3-671b", "xlstm-125m", "zamba2-7b",
         "whisper-small", "starcoder2-7b"]


@pytest.mark.parametrize("arch", CASES)
def test_decode_matches_forward(arch):
    cfg = smoke_registry()[arch]
    params = init_params(T.build_schema(cfg, 1), jax.random.PRNGKey(7),
                         jnp.dtype(cfg.dtype))
    rng = np.random.default_rng(11)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": tokens}
    enc_out = None
    if cfg.frontend == "audio_stub":
        fe = jnp.asarray(rng.standard_normal(
            (B, cfg.encoder_seq_len, cfg.d_model)), jnp.float32)
        batch["frame_embeds"] = fe
        enc_out = T._run_encoder(params, cfg,
                                 fe.astype(jnp.dtype(cfg.dtype)))
    full_logits, _, _ = T.forward(params, cfg, batch, capacity=CAP)

    cache = T.init_cache(cfg, B, S + 4)
    step_logits = []
    for i in range(S):
        lg, cache = T.decode_step(params, cfg, tokens[:, i:i + 1], cache,
                                  jnp.asarray(i, jnp.int32), enc_out=enc_out)
        step_logits.append(lg[:, 0])
    got = jnp.stack(step_logits, axis=1)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(full_logits), rtol=2e-3, atol=2e-3)
