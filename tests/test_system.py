"""End-to-end behaviour tests for the paper's system.

1. The paper's full pipeline on device: predict output structure → build an
   allocation plan → run the numeric SpGEMM into the planned buffers →
   bit-exact result vs the dense oracle, with allocation strictly smaller
   than the upper-bound method's.
2. Serving engine: batched generate with KV caches.
3. Mini sharded train: pjit train_step on a 1-device mesh with the production
   sharding rules (structure check for the dry-run path).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sparse import random as sprand
from repro.sparse.formats import spgemm_dense_oracle
from repro.core import csr, oracle, predictor, spgemm
from repro.configs.base import smoke_registry
from repro.models import transformer as T
from repro.models.schema import init_params


def test_predict_allocate_multiply_end_to_end():
    a = sprand.banded(600, 600, 24, 20, seed=21)     # CR ≈ 5-8: prediction wins
    b = sprand.banded(600, 600, 16, 22, seed=22)
    ad, bd = csr.to_device(a), csr.to_device(b)
    mda, mdb = int(a.row_nnz.max()), int(b.row_nnz.max())

    # 1. predict (paper eq. 4, device path)
    rows = predictor.draw_sample_rows(jax.random.PRNGKey(0), a.nrows,
                                      predictor.static_sample_num(a.nrows))
    pred = predictor.proposed_predict(ad, bd, rows, mda, mdb)
    flopr, _ = oracle.flop_per_row(a, b)

    # 2. allocate from the prediction
    plan = predictor.AllocationPlan.from_prediction(
        np.asarray(pred.structure), flopr, safety=1.5)
    upper_bound_capacity = int(flopr.max())
    assert plan.row_capacity < upper_bound_capacity, \
        "prediction must beat the upper-bound method"

    # 3. numeric phase into the planned buffers
    out = spgemm.spgemm(ad, bd, row_capacity=plan.row_capacity,
                        max_deg_a=mda, max_deg_b=mdb, block_rows=64)
    assert int(out.overflow) == 0, "plan must hold the true output"
    np.testing.assert_allclose(np.asarray(spgemm.dense_of(out, b.ncols)),
                               spgemm_dense_oracle(a, b), rtol=1e-4, atol=1e-4)

    # 4. predicted total within 25% (paper's worst case) of truth
    _, z = oracle.exact_structure(a, b)
    assert abs(float(pred.nnz_total) - z) / z < 0.25


def test_serve_engine_generate():
    from repro.serve import engine
    cfg = smoke_registry()["qwen2.5-32b"]
    params = init_params(T.build_schema(cfg, 1), jax.random.PRNGKey(0),
                         jnp.float32)
    sess = engine.start_session(cfg, params, batch=2, max_len=32)
    prompt = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    toks = engine.generate(sess, prompt, num_tokens=4)
    assert toks.shape == (2, 4)
    assert int(toks.max()) < cfg.vocab_size
    # greedy generation is deterministic
    sess2 = engine.start_session(cfg, params, batch=2, max_len=32)
    toks2 = engine.generate(sess2, prompt, num_tokens=4)
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(toks2))


def test_sharded_train_step_1dev_mesh():
    """The dry-run wiring (rules → specs → jit) on the 1-device mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models.sharding import make_rules, specs_from_schema
    from repro.train import optimizer as opt_mod
    from repro.train.train_loop import make_train_step

    cfg = smoke_registry()["phi3-mini-3.8b"]
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    schema = T.build_schema(cfg, mesh_model=1)
    rules = make_rules(cfg, mesh_model=1, multi_pod=False)
    pspecs = specs_from_schema(schema, rules)
    params = init_params(schema, jax.random.PRNGKey(0), jnp.float32)
    shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P))
    params = jax.device_put(params, shardings)
    oc = opt_mod.AdamWConfig(total_steps=4, warmup_steps=1)
    state = opt_mod.init_state(oc, params)
    step = jax.jit(make_train_step(cfg, oc),
                   in_shardings=(shardings, None, None),
                   out_shardings=(shardings, None, None))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                                   jnp.int32)}
    with mesh:
        p2, s2, m = step(params, state, batch)
    assert np.isfinite(float(m["loss"]))
