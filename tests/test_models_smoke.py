"""Per-arch smoke: reduced same-family config, one forward + one train step
on CPU, asserting output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import smoke_registry, registry
from repro.models import transformer as T
from repro.models.schema import init_params
from repro.train import optimizer as opt_mod
from repro.train.train_loop import make_train_step

ARCHS = list(smoke_registry().keys())
B, S = 2, 32


def _batch(cfg, rng, with_labels=True):
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.mrope_sections:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, None], (3, B, S))
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, 8, cfg.d_model)), jnp.float32)
    if cfg.frontend == "audio_stub":
        batch["frame_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_seq_len, cfg.d_model)),
            jnp.float32)
    if with_labels:
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    return batch


def test_all_archs_registered():
    assert len(ARCHS) == 10
    assert set(registry()) == set(ARCHS)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = smoke_registry()[arch]
    params = init_params(T.build_schema(cfg, 1), jax.random.PRNGKey(0),
                         jnp.dtype(cfg.dtype))
    rng = np.random.default_rng(1)
    logits, aux, _ = T.forward(params, cfg, _batch(cfg, rng, False))
    assert logits.shape == (B, S, cfg.padded_vocab())
    assert logits.dtype == jnp.float32
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_no_nan(arch):
    cfg = smoke_registry()[arch]
    params = init_params(T.build_schema(cfg, 1), jax.random.PRNGKey(0),
                         jnp.dtype(cfg.dtype))
    opt_cfg = opt_mod.AdamWConfig(lr_peak=1e-3, warmup_steps=1, total_steps=4)
    opt_state = opt_mod.init_state(opt_cfg, params)
    step = make_train_step(cfg, opt_cfg)
    rng = np.random.default_rng(2)
    new_params, new_state, metrics = step(params, opt_state, _batch(cfg, rng))
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_state.step) == 1
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes(arch):
    cfg = smoke_registry()[arch]
    params = init_params(T.build_schema(cfg, 1), jax.random.PRNGKey(0),
                         jnp.dtype(cfg.dtype))
    rng = np.random.default_rng(3)
    cache = T.init_cache(cfg, B, 64)
    enc_out = None
    if cfg.is_encoder_decoder:
        fe = jnp.asarray(rng.standard_normal((B, cfg.encoder_seq_len,
                                              cfg.d_model)),
                         jnp.dtype(cfg.dtype))
        enc_out = T._run_encoder(params, cfg, fe)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
    logits, cache2 = T.decode_step(params, cfg, tok, cache,
                                   jnp.zeros((), jnp.int32), enc_out=enc_out)
    assert logits.shape == (B, 1, cfg.padded_vocab())
    assert not bool(jnp.isnan(logits).any())
    # cache structure preserved
    assert (jax.tree_util.tree_structure(cache) ==
            jax.tree_util.tree_structure(cache2))
